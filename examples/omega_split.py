"""Demonstrate the host/device attention split ω (paper Fig. 7 / §B).

Runs the same decode batch at several ω values on the real engine, checks
token agreement, and prints the planner's predicted throughput curve for
the paper's C1 testbed alongside.

    PYTHONPATH=src python examples/omega_split.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import planner
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.core.hardware import A5000_C1
from repro.models import model as M


def main() -> None:
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    ref_tokens = None
    print("omega  host_tokens  device_tokens  agreement")
    for w in (0.0, 0.5, 1.0):
        eng = ModuleBatchingEngine(
            cfg, params, Plan(B=B, b_a=4, b_e=64, omega=w), max_seq=S + 8
        )
        out = eng.generate(toks, 8)
        if ref_tokens is None:
            ref_tokens = out
            agree = 1.0
        else:
            agree = float(jnp.mean((out == ref_tokens).astype(jnp.float32)))
        print(f"{w:4.1f}  {eng.stats.host_attn_tokens:11d}  "
              f"{eng.stats.device_attn_tokens:13d}  {agree:9.2%}")

    print("\nplanner-predicted decode throughput vs omega (C1, full model):")
    full = get_config("mixtral-8x7b")
    for i in range(0, 11, 2):
        w = i / 10
        res = planner.search_decode(full, A5000_C1, 272, omega_grid=[w])
        print(f"  w={w:.1f}: {res.estimate.throughput:7.0f} tokens/s")


if __name__ == "__main__":
    main()
