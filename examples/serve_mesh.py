"""Expert-parallel + data-parallel serving on 8 virtual CPU devices.

Demonstrates the ``repro.distributed`` subsystem end-to-end without any
accelerator hardware: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(set below, BEFORE jax imports) splits the host CPU into 8 XLA devices, a
``(1, ep)`` mesh shards every MoE layer's expert stacks across its ``model``
axis (pipelined all-to-all dispatch), and ``ReplicaServer`` fans one arrival
queue over ``dp`` data-parallel replicas of that engine.

The run serves the same requests twice — single-device and on the mesh —
and checks the generated tokens match token-for-token (the subsystem's
standing contract: distribution changes WHERE experts run, never WHICH
tokens come out).

    PYTHONPATH=src python examples/serve_mesh.py [--dp 2] [--ep 2]
"""
import argparse
import os

# must precede the first jax import: device count locks at backend init
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

from repro.configs import get_config                       # noqa: E402
from repro.core.dag_builder import Plan                    # noqa: E402
from repro.data.datasets import DatasetSpec, synthetic_requests  # noqa: E402
from repro.distributed import ReplicaServer                # noqa: E402
from repro.launch.mesh import make_debug_mesh              # noqa: E402
from repro.models import model as M                        # noqa: E402
from repro.serving.server import ServeConfig, Server       # noqa: E402
from repro.sharding.specs import ShardCtx                  # noqa: E402


def serve(cfg, params, requests, plan, serve_cfg, dp):
    if dp > 1:
        server = ReplicaServer(cfg, params, dp, plan=plan, serve=serve_cfg)
        for r in requests:
            server.submit(r)
        return server.run().merged
    server = Server(cfg, params, plan, serve_cfg)
    for r in requests:
        server.submit(r)
    return server.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--dp", type=int, default=2,
                    help="data-parallel Server replicas")
    ap.add_argument("--ep", type=int, default=2,
                    help="expert-parallel ranks (shards num_experts)")
    ap.add_argument("--ep-chunks", type=int, default=2,
                    help="pipelined all-to-all chunks per decode step")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-len", type=int, default=8)
    args = ap.parse_args()

    assert len(jax.devices()) >= args.ep, (
        f"need {args.ep} devices, have {len(jax.devices())}")
    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = DatasetSpec("mesh-demo", args.requests, args.prompt_len,
                      args.decode_len)
    requests = synthetic_requests(spec, cfg.vocab_size)
    plan = Plan(B=8, b_a=8, b_e=64, decode_chunk=4)

    base = serve(cfg, params, requests, plan,
                 ServeConfig(scheduler="static",
                             decode_len=args.decode_len), dp=1)

    sctx = ShardCtx(mesh=make_debug_mesh(1, args.ep), batch_axes=("data",),
                    model_axis="model", moe_dispatch="a2a")
    mesh_cfg = ServeConfig(scheduler="static", decode_len=args.decode_len,
                           sctx=sctx, ep_chunks=args.ep_chunks)
    print(f"mesh: dp={args.dp} replicas x ep={args.ep} expert ranks "
          f"({cfg.num_experts // args.ep} experts/rank), "
          f"ep_chunks={args.ep_chunks}")
    rep = serve(cfg, params, requests, plan, mesh_cfg, dp=args.dp)

    same = all(
        (a.tokens == b.tokens).all()
        for a, b in zip(base.request_results, rep.request_results)
    )
    print(f"tokens identical to single-device serve: {same}")
    print(f"decode throughput (this host): {rep.decode_throughput:.1f} tok/s")
    print(f"a2a exchanged: {rep.a2a_gb:.4f}GB over "
          f"{rep.collective_dispatches} collective dispatches")
    assert same, "mesh serving must be token-identical"


if __name__ == "__main__":
    main()
