"""Quickstart: plan a batching strategy and serve a small MoE with it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import planner
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.core.hardware import A5000_C2
from repro.models import model as M


def main() -> None:
    # 1. the paper's model (full config) + its planned strategy on C2
    cfg_full = get_config("mixtral-8x7b")
    res = planner.search_decode(cfg_full, A5000_C2, ctx=768)
    print("planned strategy for", cfg_full.name)
    print("   ", res.plan.describe())
    print(f"    predicted decode throughput: "
          f"{res.estimate.throughput:.0f} tokens/s "
          f"({res.evaluated} configs searched)")
    print("    critical path:", " -> ".join(res.estimate.critical[:5]))

    # 2. execute module-based batching for real on a smoke-scale variant
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, DEC = 8, 32, 12
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size
    )
    plan = Plan(B=B, b_a=4, b_e=64, omega=0.5)
    engine = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    tokens = engine.generate(prompts, DEC)
    print("\nengine generated", tokens.shape, "tokens")
    print("   stats:", engine.stats)


if __name__ == "__main__":
    main()
