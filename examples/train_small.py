"""Train a ~100M-parameter MoE for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.data.datasets import synthetic_batches
from repro.models import model as M
from repro.train.train_loop import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    # a ~100M-param OLMoE-family model (keeps the 64e top-8 routing scaled to 8e)
    base = get_config("olmoe-1b-7b", smoke=True)
    cfg = replace(
        base,
        name="olmoe-100m",
        num_layers=4,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        num_experts=8,
        experts_per_token=2,
        moe_d_ff=1024,
        vocab_size=32_000,
    )
    n = cfg.param_counts()["total"]
    print(f"training {cfg.name}: {n/1e6:.0f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batches = iter(
        (jax.numpy.asarray(t), jax.numpy.asarray(l))
        for t, l in synthetic_batches(cfg.vocab_size, args.batch, args.seq)
    )
    params, _, history = train_loop(
        cfg, params, batches, steps=args.steps, lr=1e-3, log_every=20,
        checkpoint_path=args.checkpoint, checkpoint_every=100,
    )
    assert history[-1]["loss"] < history[0]["loss"], "loss did not decrease"
    print("final loss:", history[-1]["loss"])


if __name__ == "__main__":
    main()
