"""End-to-end offline inference driver (the paper's workload, Table 4).

Serves a synthetic GSM8K-shaped dataset through the module-batching engine
with a planner-derived strategy, reporting completion time and throughput.

    PYTHONPATH=src python examples/offline_serve.py [--requests 24]
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.data.datasets import DatasetSpec, synthetic_requests
from repro.models import model as M
from repro.serving.scheduler import serve_dataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = DatasetSpec("gsm8k-shaped", args.requests, args.prompt_len,
                       args.decode_len)
    requests = synthetic_requests(spec, cfg.vocab_size)
    plan = Plan(B=args.batch, b_a=4, b_e=128, omega=0.0)
    print(f"serving {len(requests)} requests of {args.prompt_len}+"
          f"{args.decode_len} tokens on {cfg.name} with {plan.describe()}")
    report = serve_dataset(cfg, params, requests, plan, args.decode_len)
    print(f"batches:            {len(report.results)}")
    print(f"total time:         {report.total_s:.2f}s")
    print(f"decode tokens:      {report.decode_tokens}")
    print(f"decode throughput:  {report.decode_throughput:.1f} tokens/s")


if __name__ == "__main__":
    main()
