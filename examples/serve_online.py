"""Online serving through the request-lifecycle API.

Builds a ``Server`` (the facade over the module-batching engine), submits
an open-loop Poisson stream of mixed greedy/sampled requests, streams one
request's tokens through a callback, and prints per-request latency
metrics (TTFT / TPOT / queue wait) — the online protocol the offline
``serve_dataset`` wrapper cannot measure.

    PYTHONPATH=src python examples/serve_online.py [--rate 4.0]
"""
import argparse

import jax

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.data.datasets import DatasetSpec, synthetic_requests
from repro.models import model as M
from repro.serving import (
    SamplingParams, ServeConfig, Server, StreamConfig, arrivals,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--decode-len", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    requests = synthetic_requests(
        DatasetSpec("online", args.requests, 24, args.decode_len),
        cfg.vocab_size,
        prompt_lens=[24, 11, 17],
        arrivals=arrivals.poisson(args.requests, args.rate, seed=0),
    )
    # mixed batch: odd requests sample, even requests stay greedy
    for i, r in enumerate(requests):
        if i % 2:
            r.sampling = SamplingParams(temperature=args.temperature,
                                        top_k=args.top_k, seed=i)

    server = Server(
        cfg, params, Plan(B=4, b_a=4, b_e=64, omega=0.0),
        serve=ServeConfig(scheduler="continuous",
                          decode_len=args.decode_len),
        stream=StreamConfig(),
    )
    handles = [
        server.submit(r, on_token=(
            (lambda h, tok: print(f"  request 0 token: {tok}"))
            if i == 0 else None
        ))
        for i, r in enumerate(requests)
    ]
    print(f"submitted {len(handles)} requests "
          f"(poisson @ {args.rate}/s, last due {requests[-1].arrival_s:.2f}s)")
    report = server.run()

    print(f"\n{'req':>3} {'arrive':>7} {'wait':>6} {'ttft':>6} "
          f"{'tpot_ms':>8} {'tokens':>6} {'policy':>9}")
    for r in report.request_results:
        policy = "sampled" if requests[r.index].sampling else "greedy"
        print(f"{r.index:>3} {r.arrival_s:>7.2f} {r.queue_wait_s:>6.2f} "
              f"{r.ttft_s:>6.2f} {r.tpot_s * 1e3:>8.1f} "
              f"{r.tokens.size:>6} {policy:>9}")
    print(f"\ndecode throughput: {report.decode_throughput:.1f} tok/s; "
          f"TTFT p50/p95 {report.ttft_percentile(50):.2f}/"
          f"{report.ttft_percentile(95):.2f}s; "
          f"occupancy {report.occupancy:.0%}")


if __name__ == "__main__":
    main()
