"""Prefix-cache serving: shared prompt prefixes admitted without prefill.

A few-shot / system-prompt workload: every request carries the same long
instruction prefix followed by a short question.  Served twice through the
paged KV cache — cold (prefix cache off) and with the ``PrefixStore`` on —
to show hits skipping the shared span's prefill launches while producing
identical tokens.

    PYTHONPATH=src python examples/serve_prefix_cache.py [--requests 12]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import dispatch_count
from repro.models import model as M
from repro.serving.scheduler import Request, serve_dataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    help="must be all-attention without a sliding window "
                         "(prefixes are not transplantable otherwise)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prefix-len", type=int, default=48)
    ap.add_argument("--question-len", type=int, default=8)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--page-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    system_prompt = [int(t) for t in
                     rng.integers(5, cfg.vocab_size - 5, args.prefix_len)]

    def requests():
        return [
            Request(prompt=system_prompt + [
                int(t) for t in
                rng.integers(5, cfg.vocab_size - 5, args.question_len)
            ], decode_len=args.decode_len)
            for _ in range(args.requests)
        ]
    rng = np.random.default_rng(1)          # same questions for both runs
    cold_reqs = requests()
    rng = np.random.default_rng(1)
    warm_reqs = requests()

    plan = Plan(B=4, b_a=4, b_e=64, omega=0.0)
    max_seq = args.prefix_len + args.question_len + args.decode_len
    print(f"{len(cold_reqs)} requests sharing a {args.prefix_len}-token "
          f"prefix on {cfg.name}, pages of {args.page_tokens} tokens")

    d0 = dispatch_count()
    cold = serve_dataset(cfg, params, cold_reqs, plan, args.decode_len,
                         max_seq=max_seq, kv_page_tokens=args.page_tokens)
    cold_disp = dispatch_count() - d0
    d0 = dispatch_count()
    warm = serve_dataset(cfg, params, warm_reqs, plan, args.decode_len,
                         max_seq=max_seq, kv_page_tokens=args.page_tokens,
                         prefix_cache=True)
    warm_disp = dispatch_count() - d0

    same = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(cold.request_results, warm.request_results)
    )
    print(f"cold:  {cold.total_s:.2f}s, {cold_disp} module launches")
    print(f"warm:  {warm.total_s:.2f}s, {warm_disp} module launches, "
          f"{warm.prefix_hits} hits / "
          f"{warm.prefix_hits + warm.prefix_misses} lookups "
          f"(hit rate {warm.prefix_hit_rate:.0%})")
    print(f"tokens identical: {same}")
    assert same


if __name__ == "__main__":
    main()
