"""Retrace registry: every jitted hot-path callable registers under a name.

Two layers, generalizing the repo's two ad-hoc retrace counters
(``EngineStats.decode_retraces`` and ``kvcache.evict_retraces``):

* ``register_jit(name, donated=...)`` — decorator applied to each jitted
  module launch.  The registry records the jit object so the sanitizer can
  read its **compile count** (``_cache_size()``, the number of distinct
  traces XLA holds) and diff it across a steady-state region: any growth
  is a silent per-tick retrace.  Functions registered with ``donated=``
  argument names additionally get a thin wrapper that lets the active
  sanitizer verify donation aliasing on their first launch
  (``repro.analysis.donation``).

* ``TraceKeySet(name)`` — a named set of Python-side trace keys, the
  abstraction both legacy counters are now instances of: the engine adds
  one ``(n, n_host, T, ...)`` key per fused-chunk shape, the kv cache one
  padded eviction width per distinct width.  Key-set growth approximates
  retraces from the dispatcher's side (cheap, per-engine); compile counts
  are the XLA-side ground truth the sanitizer's steady-state check uses.
"""
from __future__ import annotations

import functools
import weakref
from typing import Any, Callable, Dict, Iterable, Optional, Tuple


class JitEntry:
    """One registered jitted callable."""

    def __init__(self, name: str, fn: Callable, donated: Tuple[str, ...]):
        self.name = name
        self.fn = fn                      # the jit object (has _cache_size)
        self.donated = tuple(donated)

    def compile_count(self) -> int:
        """Number of distinct traces XLA's jit cache holds for this
        function (-1 when the backend doesn't expose it)."""
        size = getattr(self.fn, "_cache_size", None)
        try:
            return int(size()) if callable(size) else -1
        except Exception:
            return -1


_JITS: Dict[str, JitEntry] = {}


def register_jit(name: str, donated: Iterable[str] = ()) -> Callable:
    """Register a jitted callable under ``name``.

    Returns the function unchanged when it donates nothing; otherwise
    wraps it so the active sanitizer (``runtime.current()``) can run the
    donation/aliasing check against the first real launch's arguments.
    """
    donated = tuple(donated)

    def deco(fn: Callable) -> Callable:
        entry = JitEntry(name, fn, donated)
        _JITS[name] = entry
        if not donated:
            return fn

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            from repro.analysis import runtime

            runtime.on_donating_launch(entry, args, kwargs)
            return fn(*args, **kwargs)

        wrapper.__registry_entry__ = entry
        return wrapper

    return deco


def registered() -> Dict[str, JitEntry]:
    return dict(_JITS)


def get(name: str) -> Optional[JitEntry]:
    return _JITS.get(name)


def compile_counts() -> Dict[str, int]:
    """Current per-name compile counts for every registered jit."""
    return {name: e.compile_count() for name, e in _JITS.items()}


def snapshot() -> Dict[str, int]:
    """Alias of ``compile_counts`` — the value to diff with ``growth``."""
    return compile_counts()


def growth(since: Dict[str, int]) -> Dict[str, int]:
    """Positive compile-count deltas since ``since`` (a ``snapshot()``).

    A non-empty result during a steady-state decode region means some
    registered hot-path function retraced — the exact failure the fused
    chunk's one-launch contract forbids."""
    out: Dict[str, int] = {}
    for name, count in compile_counts().items():
        if count < 0:
            continue
        delta = count - since.get(name, 0)
        if delta > 0:
            out[name] = delta
    return out


# ---------------------------------------------------------------------------
# TraceKeySet — the generalized ad-hoc counter
# ---------------------------------------------------------------------------
_KEYSETS: "weakref.WeakSet[TraceKeySet]" = weakref.WeakSet()


class TraceKeySet:
    """A named set of trace keys (shapes/widths/static-arg tuples) seen by
    one dispatcher.  ``add`` returns True exactly when the key is new —
    the caller's retrace accounting hangs off that (e.g. the engine bumps
    ``stats.decode_retraces``).  Instances register themselves so
    ``keyset_counts`` can fold them into the sanitizer report."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._keys: set = set()
        _KEYSETS.add(self)

    def add(self, key: Any) -> bool:
        if key in self._keys:
            return False
        self._keys.add(key)
        return True

    def __contains__(self, key: Any) -> bool:
        return key in self._keys

    def __len__(self) -> int:
        return len(self._keys)

    @property
    def count(self) -> int:
        return len(self._keys)


def keyset_counts() -> Dict[str, int]:
    """Total distinct keys per key-set name, summed over live instances
    (several engines may each hold a set under the same name).
    Informational — the steady-state check uses ``compile_counts``."""
    out: Dict[str, int] = {}
    for ks in list(_KEYSETS):
        out[ks.name] = out.get(ks.name, 0) + ks.count
    return out
