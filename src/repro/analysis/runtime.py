"""Runtime sanitizer: transfer-guarded decode regions, steady-state
retrace detection, donation verification hooks, stale-buffer poisoning.

The throughput thesis rests on invariants that only *hold at runtime*:
steady-state decode must not transfer host<->device outside the planned
``StreamWindow``/readback points, must not retrace, and must really alias
its donated buffers.  This module turns them into enforced guards:

* ``sanitize(strict=True)`` — context manager activating a sanitizer.
  While active, every engine ``decode_region()`` executes under
  ``jax.transfer_guard("disallow")`` (``strict=False`` logs instead), so
  any IMPLICIT transfer — a numpy array or Python scalar silently fed
  into device math mid-tick — raises at the offending line.  Planned
  transfers (StreamWindow fetches, sampler-state uploads, token
  readbacks, the per-tick position vector) run inside ``allowed(tag)``
  scopes, which re-enter ``transfer_guard("allow")`` and count per-tag
  occurrences into the report.

* ``Sanitizer.steady()`` — marks a steady-state region: registry compile
  counts are snapshotted at entry and diffed at exit; in strict mode any
  growth raises ``RetraceViolation`` naming the retraced functions.

* donation checks — when ``sanitize(donation=True)`` is active, the first
  launch of every ``register_jit(donated=...)`` function is verified by
  ``repro.analysis.donation.check_donation`` (compiled-HLO
  ``input_output_alias`` inspection); a dropped donation raises
  ``DonationViolation`` in strict mode.

* ``poison_stale`` — debug mode (``sanitize(poison=True)``): after a
  donating launch the engine passes its pre-launch buffer leaves here and
  any leaf XLA did NOT consume is deleted, so a retained reference into
  ``engine.cache``/``pool_k``/``pool_v`` fails loudly
  ("Array has been deleted") instead of reading stale garbage.

Ambient activation for CI: ``REPRO_SANITIZE=strict|log`` arms a
process-wide sanitizer (no code changes needed — the tier-1 suite runs
under it); ``REPRO_SANITIZE_POISON=1`` adds poisoning;
``REPRO_SANITIZE_REPORT=<path>`` dumps the JSON report at interpreter
exit (uploaded as a CI artifact from the slow job).
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
from typing import Dict, List, Optional

import jax

from repro.analysis import registry


class SanitizerError(AssertionError):
    """Base class for sanitizer contract violations."""


class RetraceViolation(SanitizerError):
    """A registered jitted function compiled during steady-state decode."""


class DonationViolation(SanitizerError):
    """A donated jitted function does not alias its donated inputs."""


class Sanitizer:
    def __init__(self, strict: bool = True, donation: bool = False,
                 poison: bool = False) -> None:
        self.strict = strict
        self.guard_mode = "disallow" if strict else "log"
        self.donation = donation
        self.poison = poison
        self.planned: Dict[str, int] = {}
        self.donation_checks: List[dict] = []
        self.steady_retraces: Dict[str, int] = {}
        self._checked: set = set()

    # -- steady-state retrace detection --------------------------------
    @contextlib.contextmanager
    def steady(self):
        """Steady-state region: no registered jit may compile inside it.

        Warm the traces first (run the identical workload once), then
        re-run under ``steady()`` — compile-count growth is a retrace."""
        base = registry.snapshot()
        yield
        grew = registry.growth(base)
        if grew:
            for name, delta in grew.items():
                self.steady_retraces[name] = (
                    self.steady_retraces.get(name, 0) + delta
                )
            if self.strict:
                raise RetraceViolation(
                    "steady-state retrace: compile count grew for "
                    + ", ".join(f"{n} (+{d})" for n, d in sorted(grew.items()))
                )

    # -- donation interception -----------------------------------------
    def check_donation_once(self, entry, args, kwargs) -> None:
        if entry.name in self._checked:
            return
        self._checked.add(entry.name)
        from repro.analysis import donation

        res = donation.check_donation(
            entry.fn, args, kwargs, entry.donated, name=entry.name
        )
        self.donation_checks.append(res.as_dict())
        if self.strict and not res.ok:
            raise DonationViolation(
                f"{entry.name}: donated inputs not aliased to outputs "
                f"({res.aliased}/{res.donated_leaves} leaves aliased"
                + (f"; {res.dropped[0]}" if res.dropped else "")
                + ")"
            )

    # -- reporting ------------------------------------------------------
    def report(self) -> dict:
        return {
            "mode": "strict" if self.strict else "log",
            "planned_transfers": dict(self.planned),
            "steady_retraces": dict(self.steady_retraces),
            "compile_counts": registry.compile_counts(),
            "trace_key_sets": registry.keyset_counts(),
            "donation_checks": list(self.donation_checks),
        }


# ---------------------------------------------------------------------------
# Active-sanitizer stack (+ ambient env activation)
# ---------------------------------------------------------------------------
_STACK: List[Sanitizer] = []
_AMBIENT: Optional[Sanitizer] = None
_AMBIENT_INIT = False


def _dump_report(san: Sanitizer, path: str) -> None:
    try:
        with open(path, "w") as f:
            json.dump(san.report(), f, indent=2, sort_keys=True)
    except OSError:
        pass


def _ambient() -> Optional[Sanitizer]:
    """Process-wide sanitizer armed from the environment (CI's strict
    flag).  Lazily constructed on first use so importing the package has
    no side effects."""
    global _AMBIENT, _AMBIENT_INIT
    if not _AMBIENT_INIT:
        _AMBIENT_INIT = True
        mode = os.environ.get("REPRO_SANITIZE", "").strip().lower()
        if mode in ("strict", "log", "1", "true"):
            _AMBIENT = Sanitizer(
                strict=mode != "log",
                poison=bool(os.environ.get("REPRO_SANITIZE_POISON")),
            )
            path = os.environ.get("REPRO_SANITIZE_REPORT")
            if path:
                atexit.register(_dump_report, _AMBIENT, path)
    return _AMBIENT


def current() -> Optional[Sanitizer]:
    """The innermost active sanitizer, or the ambient one, or None."""
    return _STACK[-1] if _STACK else _ambient()


@contextlib.contextmanager
def sanitize(strict: bool = True, donation: bool = False,
             poison: bool = False):
    """Activate a sanitizer for the body.  Yields the ``Sanitizer`` so
    callers can open ``steady()`` regions and read ``.report()`` after."""
    san = Sanitizer(strict=strict, donation=donation, poison=poison)
    _STACK.append(san)
    try:
        yield san
    finally:
        _STACK.pop()


# ---------------------------------------------------------------------------
# Region scopes (called from engine/serving hot paths)
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def decode_region():
    """A decode/steady-state region: with a sanitizer active, implicit
    host<->device transfers are disallowed (strict) or logged inside."""
    san = current()
    if san is None:
        yield
        return
    with jax.transfer_guard(san.guard_mode):
        yield


@contextlib.contextmanager
def allowed(tag: str):
    """A PLANNED transfer scope inside a decode region (StreamWindow
    ``device_put``s, sampler-state uploads, the per-tick position vector,
    token readback).  Re-enters ``transfer_guard("allow")`` and counts
    the occurrence under ``tag`` in the sanitizer report."""
    san = current()
    if san is None:
        yield
        return
    san.planned[tag] = san.planned.get(tag, 0) + 1
    with jax.transfer_guard("allow"):
        yield


def on_donating_launch(entry, args, kwargs) -> None:
    """Registry hook: called before every launch of a donated-registered
    jit; verifies aliasing once per function when donation checking is
    active."""
    san = current()
    if san is None or not san.donation:
        return
    san.check_donation_once(entry, args, kwargs)


def poison_stale(old_leaves, current_tree) -> None:
    """Debug-mode stale-buffer poisoner.

    ``old_leaves``: the donated pytree's array leaves captured BEFORE the
    launch; ``current_tree``: the rebound buffers after it.  Any old leaf
    that is not part of the current buffers and was not consumed by
    donation is deleted, so retained references fail loudly.  No-op
    unless the active sanitizer has ``poison=True``."""
    san = current()
    if san is None or not san.poison or old_leaves is None:
        return
    live = {id(leaf) for leaf in jax.tree.leaves(current_tree)}
    for leaf in old_leaves:
        if (isinstance(leaf, jax.Array) and id(leaf) not in live
                and not leaf.is_deleted()):
            leaf.delete()
