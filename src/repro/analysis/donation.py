"""Donation/aliasing checker: does a donated jit really alias in place?

``donate_argnames`` is a *request*: when XLA cannot alias a donated input
to an output (shape/dtype mismatch, layout change, or a graph that keeps
the value live) it silently copies instead, emits a Python warning, and
the whole in-place cache-update design quietly degrades to functional
whole-buffer copies.  This module makes the contract checkable:

``check_donation(fn, args, kwargs, donated)`` lowers and compiles the
jitted ``fn`` for the given arguments (ahead-of-time — lowering does NOT
consume the donated buffers, so it is safe to run right before the real
launch) and parses the compiled HLO module header's
``input_output_alias={ {out}: (param, {}, may-alias), ... }`` table: the
number of aliased parameters must equal the number of donated array
leaves, and no "donated buffers were not usable" warning may fire.

Wired into the runtime sanitizer via ``register_jit(donated=...)``: under
``sanitize(donation=True)`` every donated engine/cache launch is verified
once, on its first real argument set.
"""
from __future__ import annotations

import inspect
import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax

# one alias table entry per donated parameter, e.g. "(3, {}, may-alias)"
_ALIAS_ENTRY = re.compile(r"\(\s*(\d+)\s*,\s*\{\s*\}\s*,\s*(?:may|must)-alias\s*\)")
_DROP_WARNING = "donated buffers were not usable"


@dataclass
class DonationCheck:
    name: str
    donated_leaves: int          # array leaves under the donated arg names
    aliased: int                 # parameters the compiled HLO aliases
    dropped: List[str] = field(default_factory=list)  # drop warnings seen

    @property
    def ok(self) -> bool:
        return not self.dropped and self.aliased >= self.donated_leaves

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "donated_leaves": self.donated_leaves,
            "aliased": self.aliased,
            "dropped": list(self.dropped),
            "ok": self.ok,
        }


def _count_donated_leaves(fn, args, kwargs, donated: Sequence[str]) -> int:
    """Array leaves bound to the donated parameter names for this call."""
    inner = inspect.unwrap(fn)
    sig = inspect.signature(inner)
    bound = sig.bind(*args, **kwargs)
    total = 0
    for name in donated:
        if name in bound.arguments:
            total += sum(
                1 for leaf in jax.tree.leaves(bound.arguments[name])
                if hasattr(leaf, "shape")
            )
    return total


def alias_count(compiled_text: str) -> int:
    """Distinct aliased parameter indices in a compiled HLO module text."""
    header = compiled_text.splitlines()[0] if compiled_text else ""
    if "input_output_alias" not in header:
        return 0
    return len({m.group(1) for m in _ALIAS_ENTRY.finditer(header)})


def check_donation(fn, args, kwargs, donated: Sequence[str],
                   name: str = "") -> DonationCheck:
    """AOT-verify that ``fn(*args, **kwargs)`` aliases its donated inputs.

    ``fn`` must be the jit object; ``donated`` its ``donate_argnames``.
    Compiling ahead of time shares the trace cache with the real call and
    leaves the donated buffers alive, so callers can verify-then-launch.
    """
    leaves = _count_donated_leaves(fn, args, kwargs, donated)
    dropped: List[str] = []
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = fn.lower(*args, **kwargs).compile()
        text = compiled.as_text()
    for w in caught:
        msg = str(w.message)
        if _DROP_WARNING in msg:
            dropped.append(msg)
    return DonationCheck(
        name=name or getattr(fn, "__name__", "<jit>"),
        donated_leaves=leaves,
        aliased=alias_count(text),
        dropped=dropped,
    )


def verify_registered(calls: Dict[str, Tuple[tuple, dict]]) -> List[DonationCheck]:
    """Batch helper: ``{name: (args, kwargs)}`` over registered donated
    jits -> one ``DonationCheck`` each (tests use this directly; serving
    code goes through the sanitizer's first-launch interception)."""
    from repro.analysis import registry

    out = []
    for name, (args, kwargs) in calls.items():
        entry = registry.get(name)
        assert entry is not None and entry.donated, name
        out.append(check_donation(entry.fn, args, kwargs, entry.donated,
                                  name=name))
    return out
