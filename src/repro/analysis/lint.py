"""AST lint encoding the ROADMAP standing contracts as rules.

Stdlib-only (``ast`` + ``argparse``) — runs as a blocking CI job:

    python -m repro.analysis.lint src/repro

Rules (full rationale in ``analysis/README.md``; the ROADMAP standing
contracts carry a contract -> rule-ID table):

    MG101  host sync inside a ``@hot_path`` function: ``np.asarray`` /
           ``np.array`` / ``jax.device_get`` / ``float(...)`` /
           ``.item()`` / ``.tolist()`` / ``.block_until_ready()``.
           Every one is a device round-trip the decode tick must not pay
           implicitly; planned syncs carry an allowlist justification.
    MG102  ``jax.jit`` construction inside a ``for``/``while`` loop — a
           fresh jit object per iteration compiles per tick.
    MG103  mutation of a frozen config dataclass instance (assignment to
           an attribute of ``cfg``/``plan``/``serve``/... names, or
           ``object.__setattr__`` outside ``__init__``/``__post_init__``).
    MG104  a module-level jitted function calls
           ``lax.dynamic_update_slice(_in_dim)`` — the in-place cache
           write — without ``donate_argnames``/``donate_argnums``: the
           "update" silently materializes a full functional copy.
    MG105  ``jax.device_put`` outside the planned StreamWindow modules
           (``serving/weights.py``, ``serving/cache.py``) — all htod
           weight/KV traffic flows through the accounted window.
    MG106  an allowlist comment without a justification: every
           suppression must say WHY the line is exempt.
    MG107  a collective (``all_to_all`` / ``psum`` / ``all_gather`` / ...)
           in ``repro.distributed`` outside a ``@register_jit`` module —
           every mesh collective must live in a named, registry-tracked
           jitted module so the retrace ledger and the sanitizer see it.

Allowlist syntax — on the FIRST line of the flagged statement:

    x = np.asarray(dev)   # lint: allow[MG101] planned once-per-chunk readback

Multiple rules: ``allow[MG101,MG105]``.  The free text after the bracket
is the justification and must be non-empty (else MG106).
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "MG101": "host sync / device readback inside a @hot_path function",
    "MG102": "jax.jit construction inside a loop retraces per iteration",
    "MG103": "mutation of a frozen config dataclass instance",
    "MG104": "jitted dynamic_update_slice writer without donate_argnames",
    "MG105": "jax.device_put outside the planned StreamWindow modules",
    "MG106": "lint allowlist entry without a justification",
    "MG107": "collective in repro.distributed outside a register_jit module",
}

HOT_PATH_NAMES = {"hot_path"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
HOST_SYNC_NP_FUNCS = {"asarray", "array"}
# modules whose jax.device_put IS the planned transfer window
DEVICE_PUT_OK = ("serving/weights.py", "serving/cache.py")
# mesh collectives that MG107 requires inside @register_jit modules
COLLECTIVE_NAMES = {"all_to_all", "psum", "pmean", "all_gather", "ppermute",
                    "psum_scatter", "pmax", "pmin"}
REGISTER_JIT_NAMES = {"register_jit"}
# names conventionally bound to frozen config dataclasses
# (ModelConfig / Plan / ServeConfig / StreamConfig / CacheConfig /
#  SamplingParams / HardwareProfile)
CONFIG_NAMES = {"cfg", "config", "plan", "serve", "serve_cfg", "stream",
                "stream_cfg", "cache_config", "cc", "sampling_params", "sp",
                "hw"}
MUTATING_SETATTR_OK_SCOPES = {"__init__", "__post_init__", "__new__"}

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\[([A-Za-z0-9_,\s]+)\]\s*(.*?)\s*$"
)


@dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _parse_allowlist(text: str):
    """line -> (set of allowed rule IDs, justification)."""
    allow: Dict[int, Tuple[Set[str], str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            allow[i] = (rules, m.group(2).strip())
    return allow


def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute(Name('jax'), 'jit'); '' when unresolvable."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _decorator_names(fn: ast.AST) -> List[str]:
    names = []
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        names.append(_dotted(target))
    return names


def _is_hot_path(fn: ast.AST) -> bool:
    return any(name.split(".")[-1] in HOT_PATH_NAMES
               for name in _decorator_names(fn))


def _contains(node: ast.AST, dotted: str) -> Optional[ast.AST]:
    """First descendant whose dotted name is ``dotted`` (e.g. 'jax.jit')."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and _dotted(sub) == dotted:
            return sub
    return None


def _jit_decoration(fn: ast.FunctionDef):
    """(is_jitted, donates) from the decorator list: matches ``@jax.jit``,
    ``@functools.partial(jax.jit, ...)`` and ``@jax.jit(...)`` forms."""
    jitted = donates = False
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target)
        is_partial = name.endswith("partial") and isinstance(dec, ast.Call)
        if name == "jax.jit" or (
            is_partial and dec.args
            and _dotted(dec.args[0]) == "jax.jit"
        ):
            jitted = True
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg in ("donate_argnames", "donate_argnums"):
                        donates = True
    return jitted, donates


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str, relpath: str) -> None:
        self.path = path
        self.relpath = relpath.replace("\\", "/")
        self.findings: List[Finding] = []
        self._hot_depth = 0
        self._reg_jit_depth = 0
        self._scope: List[str] = []

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(
            Finding(self.path, getattr(node, "lineno", 0), rule, message)
        )

    # -- function scope tracking ---------------------------------------
    def _visit_function(self, node) -> None:
        hot = _is_hot_path(node)
        # MG107 scope: a function decorated @register_jit(...) — nested
        # bodies (e.g. the shard_map closure) inherit the registered scope
        reg = any(name.split(".")[-1] in REGISTER_JIT_NAMES
                  for name in _decorator_names(node))
        self._check_mg104(node)
        self._hot_depth += 1 if hot else 0
        self._reg_jit_depth += 1 if reg else 0
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()
        self._reg_jit_depth -= 1 if reg else 0
        self._hot_depth -= 1 if hot else 0

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- MG104: jitted dus writer must donate --------------------------
    def _check_mg104(self, fn) -> None:
        jitted, donates = _jit_decoration(fn)
        if not jitted or donates:
            return
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Attribute) and sub.attr in (
                "dynamic_update_slice", "dynamic_update_slice_in_dim"
            ):
                self._flag(
                    sub, "MG104",
                    f"jitted '{fn.name}' writes via {sub.attr} without "
                    "donate_argnames — the in-place update silently "
                    "becomes a whole-buffer copy",
                )
                return

    # -- MG101 / MG105: calls -----------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name == "jax.device_put" and not self.relpath.endswith(
            DEVICE_PUT_OK
        ):
            self._flag(
                node, "MG105",
                "jax.device_put outside serving/weights.py / "
                "serving/cache.py — htod traffic must flow through the "
                "planned StreamWindow",
            )
        if self._hot_depth > 0:
            leaf = name.split(".")[-1]
            if name in ("np.asarray", "np.array", "numpy.asarray",
                        "numpy.array", "jax.device_get"):
                self._flag(node, "MG101",
                           f"{name} inside a @hot_path function is a "
                           "device->host sync per call")
            elif isinstance(node.func, ast.Attribute) and (
                leaf in HOST_SYNC_METHODS
            ):
                self._flag(node, "MG101",
                           f".{leaf}() inside a @hot_path function is a "
                           "blocking host sync")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id == "float" and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                self._flag(node, "MG101",
                           "float(...) on a device value inside a "
                           "@hot_path function forces a blocking readback")
        # MG107: collectives in repro.distributed must sit (lexically)
        # inside a @register_jit module so retrace/sanitizer ledgers see
        # them — a bare lax.psum in helper code escapes both
        if (self.relpath.startswith("distributed/")
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in COLLECTIVE_NAMES
                and self._reg_jit_depth == 0):
            self._flag(
                node, "MG107",
                f"collective '{node.func.attr}' outside a @register_jit "
                "module — mesh collectives must live in registry-tracked "
                "jitted modules",
            )
        # MG103: object.__setattr__ outside construction scopes
        if (name == "object.__setattr__"
                and not (self._scope
                         and self._scope[-1] in MUTATING_SETATTR_OK_SCOPES)):
            self._flag(node, "MG103",
                       "object.__setattr__ mutates a frozen dataclass "
                       "outside __init__/__post_init__")
        self.generic_visit(node)

    # -- MG102: jit construction in loops ------------------------------
    def _visit_loop(self, node) -> None:
        for stmt in node.body + getattr(node, "orelse", []):
            hit = _contains(stmt, "jax.jit")
            if hit is not None:
                self._flag(hit, "MG102",
                           "jax.jit constructed inside a loop — a fresh "
                           "jit object per iteration compiles per tick")
        self.generic_visit(node)

    visit_For = _visit_loop
    visit_While = _visit_loop

    # -- MG103: frozen-config attribute assignment ---------------------
    def _config_target(self, target: ast.AST) -> Optional[str]:
        """cfg.x = ... / self.cfg.x = ... — an attribute being SET on an
        object bound to a config name (NOT ``self.cfg = cfg``, which
        binds the attribute on self)."""
        if not isinstance(target, ast.Attribute):
            return None
        base = target.value
        if isinstance(base, ast.Name) and base.id in CONFIG_NAMES:
            return base.id
        if isinstance(base, ast.Attribute) and base.attr in CONFIG_NAMES:
            return base.attr
        return None

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            name = self._config_target(target)
            if name:
                self._flag(node, "MG103",
                           f"assignment into '{name}.{target.attr}' — "
                           "config dataclasses are frozen; use "
                           "dataclasses.replace")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        name = self._config_target(node.target)
        if name:
            self._flag(node, "MG103",
                       f"augmented assignment into '{name}."
                       f"{node.target.attr}' — config dataclasses are "
                       "frozen")
        self.generic_visit(node)


def check_source(text: str, path: str = "<memory>",
                 relpath: Optional[str] = None) -> List[Finding]:
    """Lint one source text; returns unsuppressed findings (allowlisted
    lines are dropped, undocumented allowlist entries become MG106)."""
    tree = ast.parse(text, filename=path)
    checker = _Checker(path, relpath if relpath is not None else path)
    checker.visit(tree)
    allow = _parse_allowlist(text)
    findings = []
    used: Set[int] = set()
    seen: Set[Tuple[int, str]] = set()
    deduped = []
    for f in checker.findings:
        if (f.line, f.rule) not in seen:
            seen.add((f.line, f.rule))
            deduped.append(f)
    for f in deduped:
        entry = allow.get(f.line)
        if entry is not None and f.rule in entry[0]:
            used.add(f.line)
            if not entry[1]:
                findings.append(Finding(
                    path, f.line, "MG106",
                    f"allowlist entry for {f.rule} has no justification",
                ))
            continue
        findings.append(f)
    # allowlist comments must justify even when nothing fired (a stale
    # suppression with no reason is still undocumented)
    for line, (rules, reason) in allow.items():
        if line not in used and not reason:
            findings.append(Finding(
                path, line, "MG106",
                f"allowlist entry for {','.join(sorted(rules))} has no "
                "justification",
            ))
    return findings


def _iter_py_files(paths: Sequence[str]) -> Iterable[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def _relpath(path: Path) -> str:
    """Path relative to its 'repro' package root (rule MG105 matches on
    package-relative module paths)."""
    parts = path.as_posix().split("/")
    if "repro" in parts:
        idx = len(parts) - 1 - parts[::-1].index("repro")
        return "/".join(parts[idx + 1:])
    return path.as_posix()


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in _iter_py_files(paths):
        text = path.read_text()
        findings.extend(
            check_source(text, path=str(path), relpath=_relpath(path))
        )
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Standing-contract AST lint (rules MG101-MG107).",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    args = ap.parse_args(argv)
    findings = lint_paths(args.paths)
    for f in findings:
        print(f.render())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
