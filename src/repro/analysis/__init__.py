"""Static-analysis + sanitizer subsystem: the standing contracts as rules.

Three layers (see ``analysis/README.md`` for the rule catalogue):

* runtime sanitizer — ``analysis.sanitize(strict=True)`` wires
  ``jax.transfer_guard`` around engine decode regions and diffs the
  retrace registry across steady-state regions;
* donation checker — compiled-HLO ``input_output_alias`` verification
  for every ``register_jit(donated=...)`` launch, plus the debug-mode
  stale-buffer poisoner;
* AST lint — ``python -m repro.analysis.lint src/repro`` (rules
  MG101–MG107, stdlib-only, blocking in CI).
"""
from repro.analysis.donation import DonationCheck, check_donation
from repro.analysis.markers import hot_path, is_hot_path
from repro.analysis.registry import TraceKeySet, register_jit
from repro.analysis.runtime import (
    DonationViolation,
    RetraceViolation,
    Sanitizer,
    SanitizerError,
    allowed,
    decode_region,
    poison_stale,
    sanitize,
)

__all__ = [
    "DonationCheck",
    "DonationViolation",
    "RetraceViolation",
    "Sanitizer",
    "SanitizerError",
    "TraceKeySet",
    "allowed",
    "check_donation",
    "decode_region",
    "hot_path",
    "is_hot_path",
    "poison_stale",
    "register_jit",
    "sanitize",
]
