"""Source markers consumed by the analysis subsystem.

``@hot_path`` is a no-op at runtime: it tags a function as part of the
decode hot path so the AST lint (``repro.analysis.lint``, rule MG101)
holds it to the no-host-sync contract — no ``np.asarray`` / ``float()`` /
``.item()`` / ``.tolist()`` / ``.block_until_ready()`` on device values
inside it, except at lines carrying a justified allowlist comment
(``# lint: allow[MG101] <why this sync is planned>``).

The marker is matched BY NAME in the AST (``hot_path`` or
``markers.hot_path`` in a decorator list), so the lint needs no imports
to resolve it; the runtime attribute is only for introspection.
"""
from __future__ import annotations

HOT_PATH_ATTR = "__hot_path__"


def hot_path(fn):
    """Mark ``fn`` as a decode hot-path function (lint rule MG101 scope)."""
    setattr(fn, HOT_PATH_ATTR, True)
    return fn


def is_hot_path(fn) -> bool:
    return bool(getattr(fn, HOT_PATH_ATTR, False))
