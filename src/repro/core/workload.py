"""Analytic per-module workload model (FLOPs / bytes / memory).

These are the "profiled" quantities of the paper's scheduler (§B: modules
are profiled offline across batch sizes).  With no physical GPU in this
container, profiling is replaced by closed-form counts derived from the
architecture — the same quantities the paper's profiler measures.

All byte figures assume the config dtype (bf16 = 2 bytes).  ``ctx`` is the
context length visible to attention at decode time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig

BYTES = 2  # bf16


def dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if "16" in cfg.dtype else 4


# ---------------------------------------------------------------------------
# Per-layer weight sizes
# ---------------------------------------------------------------------------
def attn_weight_bytes(cfg: ModelConfig) -> float:
    q = cfg.num_heads * cfg.head_dim
    kv = cfg.num_kv_heads * cfg.head_dim
    return (cfg.d_model * q + 2 * cfg.d_model * kv + q * cfg.d_model) * BYTES


def expert_weight_bytes(cfg: ModelConfig) -> float:
    """One expert's weights."""
    return 3 * cfg.d_model * cfg.moe_d_ff * BYTES


def expert_buffer_bytes(cfg: ModelConfig, capacity: int) -> float:
    """Device bytes of the grouped-dispatch buffers at per-expert capacity
    ``C = b_e``: the (E, C, D) token buffer, its (E, C, D) output, and the
    (E, C, F) gate/up intermediates of the grouped FFN (Eq. 3's S_IS term
    for the expert module)."""
    if not cfg.has_moe:
        return 0.0
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    return e * capacity * (2 * d + 2 * f) * BYTES


def dense_ffn_weight_bytes(cfg: ModelConfig) -> float:
    return 3 * cfg.d_model * cfg.d_ff * BYTES


def moe_layer_weight_bytes(cfg: ModelConfig) -> float:
    """One MoE layer's streamable FFN weights: all expert stacks + the
    router (stored f32).  This is the unit the streamed store fetches —
    the grouped GEMM needs every expert of the layer at once."""
    if not cfg.has_moe:
        return 0.0
    return cfg.num_experts * expert_weight_bytes(cfg) + cfg.d_model * cfg.num_experts * 4


def ssm_weight_bytes(cfg: ModelConfig) -> float:
    d, di, ns, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    return (d * (2 * di + 2 * ns + nh) + di * d) * BYTES


def model_bytes(cfg: ModelConfig) -> float:
    return cfg.param_counts()["total"] * BYTES


def kv_bytes_per_token_layer(cfg: ModelConfig) -> float:
    """KV-cache bytes appended per token for one attention layer."""
    return 2 * cfg.num_kv_heads * cfg.head_dim * BYTES


def kv_page_frame_bytes(cfg: ModelConfig, page_tokens: int) -> float:
    """Bytes of ONE page frame across every attention layer (K + V):
    the allocation unit of the paged tiered cache
    (``serving.cache.KVPageTable.frame_bytes``)."""
    n_attn = sum(
        1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn"
    )
    return n_attn * page_tokens * kv_bytes_per_token_layer(cfg)


def kv_bytes_per_seq(cfg: ModelConfig, ctx: int, page_tokens: int = 0) -> float:
    """Full KV cache of one sequence across all attention layers.

    ``page_tokens > 0`` rounds each attention span UP to whole pages — the
    paged cache allocates frame-granular, so admission must charge the
    rounded extent (a 17-token span holds a 32-token page at
    ``page_tokens=32``)."""
    total = 0.0
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            span = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
            if page_tokens > 0:
                span = -(-span // page_tokens) * page_tokens
            total += span * kv_bytes_per_token_layer(cfg)
    # SSM layers carry an O(1) state instead
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "ssm":
            total += (
                cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
                + cfg.ssm_conv_width * (cfg.ssm_d_inner + 2 * cfg.ssm_state) * BYTES
            )
    return total


# ---------------------------------------------------------------------------
# Per-module FLOPs (per token unless stated)
# ---------------------------------------------------------------------------
def pre_attn_flops(cfg: ModelConfig) -> float:
    q = cfg.num_heads * cfg.head_dim
    kv = cfg.num_kv_heads * cfg.head_dim
    return 2 * cfg.d_model * (q + 2 * kv)


def post_attn_flops(cfg: ModelConfig) -> float:
    return 2 * cfg.num_heads * cfg.head_dim * cfg.d_model


def attn_mech_flops_decode(cfg: ModelConfig, ctx: int) -> float:
    """QK^T + PV for ONE new token against `ctx` cached tokens."""
    span = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    return 4 * cfg.num_heads * cfg.head_dim * span


def attn_mech_flops_prefill(cfg: ModelConfig, seq: int) -> float:
    """Per sequence (causal: ~S^2/2 each for QK^T and PV)."""
    span = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    return 4 * cfg.num_heads * cfg.head_dim * seq * span / 2

def expert_flops_per_token(cfg: ModelConfig) -> float:
    """FLOPs for one token in ONE expert (3 GEMMs, gated FFN)."""
    return 6 * cfg.d_model * cfg.moe_d_ff


def dense_ffn_flops(cfg: ModelConfig) -> float:
    return 6 * cfg.d_model * cfg.d_ff


def router_flops(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.num_experts


def ssm_flops_per_token(cfg: ModelConfig) -> float:
    d, di, ns, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    proj = 2 * d * (2 * di + 2 * ns + nh) + 2 * di * d
    scan = 6 * di * ns          # state update + readout
    return proj + scan


def lm_head_flops(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab_size


# ---------------------------------------------------------------------------
# Layer census
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerCensus:
    n_attn: int
    n_ssm: int
    n_moe: int
    n_dense_ffn: int


def census(cfg: ModelConfig) -> LayerCensus:
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    n_ssm = cfg.num_layers - n_attn
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.ffn_kind(i) == "moe")
    n_dense = sum(
        1
        for i in range(cfg.num_layers)
        if cfg.ffn_kind(i) == "dense" and cfg.d_ff > 0
    )
    return LayerCensus(n_attn, n_ssm, n_moe, n_dense)


def dense_module_bytes_per_layer(cfg: ModelConfig) -> float:
    """Weights of the per-layer *dense* modules (attention / SSM / shared) —
    sizes the paper's single dense-module prefetch buffer (S_Dense)."""
    per = 0.0
    c = census(cfg)
    if c.n_attn:
        per = max(per, attn_weight_bytes(cfg))
    if c.n_ssm:
        per = max(per, ssm_weight_bytes(cfg))
    if c.n_dense_ffn:
        per = max(per, dense_ffn_weight_bytes(cfg))
    return per


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(1, n) — the capacity-bucket rounding
    shared by the planner's prefill Eq. 3 charge and the engine's grouped-
    prefill dispatch buffer (bounded trace-key variety: one bucket per
    doubling, not one per distinct measured load)."""
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Weight-residency policy (S_Params / S_Expert of Table 2, realized)
# ---------------------------------------------------------------------------
def mixer_weight_bytes(cfg: ModelConfig, kind: str) -> float:
    """Sequence-mixer module weights (norms included) for one layer."""
    norms = 2 * cfg.d_model * BYTES
    if kind == "attn":
        return attn_weight_bytes(cfg) + norms
    return ssm_weight_bytes(cfg) + norms


def ffn_module_weight_bytes(cfg: ModelConfig, ffn: str) -> float:
    """FFN-stage module weights for one layer ('moe' or 'dense')."""
    if ffn == "moe":
        return moe_layer_weight_bytes(cfg)
    return dense_ffn_weight_bytes(cfg) if cfg.d_ff > 0 else 0.0


def base_weight_bytes(cfg: ModelConfig) -> float:
    """Always-resident weights: embedding, final norm, lm_head.  They are
    touched every token (embed/head bracket each step), so the store pins
    them regardless of the budget."""
    per = cfg.vocab_size * cfg.d_model * BYTES
    total = per + cfg.d_model * BYTES
    if not cfg.tie_embeddings:
        total += per
    return total


def stream_module_bytes(cfg: ModelConfig, predict_topk: int = 0) -> float:
    """Largest per-layer streamed working set — sizes ONE slot of the
    device-side stream buffer.  The store stages a whole layer's streamed
    modules together (mixer AND FFN stage when nothing is resident), so a
    slot is charged as the worst single layer's total, not the largest
    individual module.

    ``predict_topk > 0`` models predictive per-expert streaming: only the
    predicted expert set (k-hat experts) is staged per MoE layer instead of
    the whole stack, and the layer's norm2/router are pinned resident by the
    store, so an MoE layer's streamed FFN bytes shrink from
    ``moe_layer_weight_bytes`` to ``k-hat * expert_weight_bytes``.
    Mispredicted experts are fetched on demand through the same window and
    are transient, so they do not grow the steady-state slot."""
    per = 0.0
    for i in range(cfg.num_layers):
        ffn = cfg.ffn_kind(i)
        if ffn == "moe" and predict_topk > 0:
            khat = min(cfg.num_experts, int(predict_topk))
            ffn_bytes = khat * expert_weight_bytes(cfg)
        else:
            ffn_bytes = ffn_module_weight_bytes(cfg, ffn)
        layer = mixer_weight_bytes(cfg, cfg.layer_kind(i)) + ffn_bytes
        per = max(per, layer)
    return per


def stream_buffer_bytes(
    cfg: ModelConfig, depth: int = 2, predict_topk: int = 0
) -> float:
    """Device bytes of the double-buffered weight-stream window (S_Expert):
    ``depth`` slots of the largest streamed module — layer l's working set
    plus layer l+1's in-flight prefetch.  The Eq. 3 sibling of
    ``expert_buffer_bytes`` for weight streaming.  With ``predict_topk``
    set, a slot holds the expected predicted-expert set, not the worst
    whole-layer stack (see ``stream_module_bytes``)."""
    return depth * stream_module_bytes(cfg, predict_topk=predict_topk)


@dataclass(frozen=True)
class ResidencyPlan:
    """Greedy device-residency split of the model weights under a byte
    budget (``Plan.s_params``).  The SAME policy drives the planner's cost
    model (``dag_builder``) and the executor's ``serving.weights.ParamStore``
    — what the planner predicts resident is exactly what the store pins.

    Fill order: base (embed/head/final-norm, always pinned) -> sequence
    mixers + norms in layer order -> dense FFNs -> MoE expert stacks in
    layer order.  Mixers are tiny and touched every layer; expert stacks
    are the bulk and the last to fit (paper Fig. 6: S_Expert streams them).
    """

    base_bytes: float                      # always-resident bytes
    resident_bytes: float                  # realized total incl. base
    mixer_resident: tuple                  # per layer: bool
    ffn_resident: tuple                    # per layer: bool (True if no FFN)
    spare_bytes: float = 0.0               # budget left after greedy fill;
    #                                        the store's hot-expert LRU may
    #                                        promote experts into these bytes

    @property
    def fully_resident(self) -> bool:
        return all(self.mixer_resident) and all(self.ffn_resident)

    def n_streamed(self) -> int:
        return sum(not r for r in self.mixer_resident) + sum(
            not r for r in self.ffn_resident
        )


def plan_residency(cfg: ModelConfig, s_params: Optional[float]) -> ResidencyPlan:
    """Realize ``Plan.s_params`` as a concrete resident set (greedy fill).

    ``s_params=None`` — or any budget >= ``model_bytes`` — means everything
    resident (no streaming): the per-module size formulas are a POLICY, not
    exact array bytes (e.g. the router is stored f32 while ``model_bytes``
    charges every param at ``BYTES``), so without this rule a budget of
    exactly ``model_bytes`` would strand the last greedy module host-side
    and break the planner's fully-resident contract.  The base set is
    pinned even when it exceeds the budget — the executor cannot run
    without embeddings/head on device — so ``resident_bytes`` may exceed a
    tiny ``s_params``.
    """
    L = cfg.num_layers
    if s_params is None or s_params >= model_bytes(cfg):
        return ResidencyPlan(
            base_weight_bytes(cfg), model_bytes(cfg),
            (True,) * L, (True,) * L,
        )
    base = base_weight_bytes(cfg)
    budget = max(0.0, float(s_params) - base)
    mixer = [False] * L
    ffn = [False] * L
    used = base
    # greedy order: mixers, dense FFNs, then expert stacks
    order = (
        [("mixer", i, mixer_weight_bytes(cfg, cfg.layer_kind(i)))
         for i in range(L)]
        + [("ffn", i, ffn_module_weight_bytes(cfg, "dense"))
           for i in range(L) if cfg.ffn_kind(i) == "dense"]
        + [("ffn", i, ffn_module_weight_bytes(cfg, "moe"))
           for i in range(L) if cfg.ffn_kind(i) == "moe"]
    )
    for which, i, nbytes in order:
        if nbytes <= 0.0:                  # no module => trivially resident
            (mixer if which == "mixer" else ffn)[i] = True
            continue
        if nbytes <= budget:
            (mixer if which == "mixer" else ffn)[i] = True
            budget -= nbytes
            used += nbytes
    # layers without an FFN module count as resident
    for i in range(L):
        if cfg.ffn_kind(i) == "dense" and cfg.d_ff <= 0:
            ffn[i] = True
    return ResidencyPlan(base, used, tuple(mixer), tuple(ffn), budget)


# ---------------------------------------------------------------------------
# Intermediate-state sizing (constrains b_a in Eq. 3)
# ---------------------------------------------------------------------------
def intermediate_bytes_decode(cfg: ModelConfig, b_a: int, ctx: int) -> float:
    """Peak activation bytes for an attention micro-batch at decode."""
    h = cfg.num_heads
    hd = cfg.head_dim
    qkv = 3 * h * hd * BYTES
    scores = h * min(ctx, cfg.sliding_window or ctx) * 4      # f32 row
    hidden = 2 * cfg.d_model * BYTES
    return b_a * (qkv + scores + hidden)


def intermediate_bytes_prefill(cfg: ModelConfig, b_a: int, seq: int) -> float:
    """Peak activation bytes for a prefill micro-batch (flash-blocked)."""
    h, hd = cfg.num_heads, cfg.head_dim
    block = 512
    per_tok = (3 * h * hd + 4 * cfg.d_model) * BYTES
    flash = h * block * 4
    return b_a * seq * (per_tok + flash)
