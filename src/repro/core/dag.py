"""Job DAG with channel serialization and critical-path DP (paper Eq. 4).

Inference is a DAG of jobs; each job is computation or a memory copy and
executes on one *channel* (gpu / cpu / htod / dtoh).  Jobs on the same
channel serialize in submission order (hardware queues), which the builder
encodes as implicit edges.  ``earliest_finish`` computes

    dp[v] = max_{u in preds(v)} dp[u] + cost(v)

over the topological order (nodes are appended in topological order by
construction).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

CHANNELS = ("gpu", "cpu", "htod", "dtoh", "comm")


@dataclass
class Job:
    name: str
    channel: str
    duration: float
    deps: List[int] = field(default_factory=list)
    finish: float = 0.0


class JobDag:
    def __init__(self) -> None:
        self.jobs: List[Job] = []
        self._last_on_channel: Dict[str, int] = {}

    def add(
        self,
        name: str,
        channel: str,
        duration: float,
        deps: Optional[List[int]] = None,
        serialize: bool = True,
    ) -> int:
        """Append a job (topological order).  Returns its id."""
        assert channel in CHANNELS, channel
        deps = list(deps or [])
        if serialize and channel in self._last_on_channel:
            deps.append(self._last_on_channel[channel])
        jid = len(self.jobs)
        self.jobs.append(Job(name, channel, max(duration, 0.0), deps))
        self._last_on_channel[channel] = jid
        return jid

    def earliest_finish(self) -> float:
        """Critical-path DP over the topological (insertion) order."""
        best = 0.0
        for j in self.jobs:
            start = max((self.jobs[d].finish for d in j.deps), default=0.0)
            j.finish = start + j.duration
            best = max(best, j.finish)
        return best

    def channel_busy(self) -> Dict[str, float]:
        busy: Dict[str, float] = {c: 0.0 for c in CHANNELS}
        for j in self.jobs:
            busy[j.channel] += j.duration
        return busy

    def critical_path(self) -> List[str]:
        """Names along the critical path (for diagnostics)."""
        if not self.jobs:
            return []
        self.earliest_finish()
        v = max(range(len(self.jobs)), key=lambda i: self.jobs[i].finish)
        path = []
        while True:
            path.append(self.jobs[v].name)
            deps = self.jobs[v].deps
            if not deps:
                break
            v = max(deps, key=lambda i: self.jobs[i].finish)
        return list(reversed(path))
