"""Build MoE-offloading job DAGs (paper Fig. 6) and estimate phase runtimes.

One DAG is built per *distinct layer type* (attention+MoE, attention+dense,
SSM+MoE, ...) and the model time sums layer-type times weighted by their
census — matching the paper's per-layer DAG with P-D disaggregation
(separate DAG classes for prefill and decode).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag import JobDag
from repro.core.hardware import HardwareProfile


@dataclass(frozen=True)
class Plan:
    """A module-based batching strategy (the search variables of Table 2)."""

    B: int                 # accumulated batch (sequences) at the MoE stage
    b_a: int               # attention micro-batch (sequences)
    b_e: int               # per-expert token capacity C of the grouped
    #                        (E, C, D) dispatch buffer; routed copies beyond
    #                        it are dropped (engine counts them in stats)
    omega: float = 0.0     # fraction of attention computed on the host CPU
    s_expert: float = 0.0  # reserved expert prefetch buffer (bytes)
    s_params: float = 0.0  # model weights cached resident on device (bytes)
    phase: str = "decode"
    kv_on_gpu: bool = False     # baselines keep the KV cache device-resident
    weight_reuse: int = 1       # FlexGen-style rounds reusing fetched weights
    decode_chunk: int = 8       # fused decode chunk T: tokens generated per
    #                             device dispatch when the engine's fused path
    #                             is eligible (planner.select_decode_chunk
    #                             sizes it from the admission cadence; the
    #                             scheduler further clamps it to the shortest
    #                             live request so no eviction is due mid-chunk)
    kv_page_tokens: int = 0     # paged tiered KV cache: tokens per page frame
    #                             (0 = legacy contiguous buffers)
    kv_device_pages: int = 0    # device page-pool frames the plan reserves
    #                             (planner.kv_device_pool_frames sizes it from
    #                             the Eq. 3 spare; 0 with paging on = Mode A,
    #                             everything device-resident)
    predict_topk: int = 0       # predictive per-expert streaming: k-hat
    #                             experts staged per streamed MoE layer from
    #                             layer l's gate-logit prediction (0 = whole-
    #                             stack staging).  Sizes the stream-window
    #                             slot and the expected expert htod per layer;
    #                             mispredictions demand-fetch, so correctness
    #                             never depends on it
    ep_chunks: int = 1          # expert-parallel pipeline chunks: the decode
    #                             batch splits into this many independent
    #                             all-to-all+FFN stages so chunk k+1's
    #                             dispatch overlaps chunk k's expert GEMMs
    #                             (distributed.ep_engine; 1 = serial a2a).
    #                             Purely a schedule knob — tokens identical

    def describe(self) -> str:
        out = (
            f"phase={self.phase} B={self.B} b_a={self.b_a} b_e={self.b_e} "
            f"w={self.omega:.1f} S_exp={self.s_expert/1e9:.1f}GB "
            f"S_par={self.s_params/1e9:.1f}GB reuse={self.weight_reuse} "
            f"T={self.decode_chunk}"
        )
        if self.kv_page_tokens:
            out += (f" pages={self.kv_page_tokens}tok"
                    f"x{self.kv_device_pages}dev")
        if self.predict_topk:
            out += f" pred_k={self.predict_topk}"
        if self.ep_chunks > 1:
            out += f" ep_chunks={self.ep_chunks}"
        return out


@dataclass
class PhaseEstimate:
    throughput: float            # tokens/s
    t_model: float               # seconds per full model pass
    tokens: float                # tokens produced/consumed per pass
    htod_bytes: float
    dtoh_bytes: float
    layer_times: Dict[str, float] = field(default_factory=dict)
    critical: List[str] = field(default_factory=list)


def _miss_fractions(cfg: ModelConfig, plan: Plan) -> Dict[str, float]:
    """Per-module-class htod miss fractions under the REALIZED resident set.

    ``plan.s_params`` is no longer a scalar discount applied uniformly: the
    greedy residency policy (``workload.plan_residency`` — the same one the
    executor's ``ParamStore`` pins weights with) decides which concrete
    modules live on device, and each weight class is charged only for its
    non-resident layers.  ``weight_reuse`` (FlexGen-style rounds) divides
    the miss as before.
    """
    rp = W.plan_residency(cfg, plan.s_params if plan.s_params > 0 else 0.0)
    reuse = max(plan.weight_reuse, 1)

    def frac(flags) -> float:
        flags = list(flags)
        if not flags:
            return 0.0
        return sum(not f for f in flags) / len(flags) / reuse

    attn_f = [rp.mixer_resident[i] for i in range(cfg.num_layers)
              if cfg.layer_kind(i) == "attn"]
    ssm_f = [rp.mixer_resident[i] for i in range(cfg.num_layers)
             if cfg.layer_kind(i) == "ssm"]
    moe_f = [rp.ffn_resident[i] for i in range(cfg.num_layers)
             if cfg.ffn_kind(i) == "moe"]
    dense_f = [rp.ffn_resident[i] for i in range(cfg.num_layers)
               if cfg.ffn_kind(i) == "dense" and cfg.d_ff > 0]
    return {
        "attn": frac(attn_f),
        "ssm": frac(ssm_f),
        "moe": frac(moe_f),
        "dense": frac(dense_f),
    }


# ---------------------------------------------------------------------------
# Decode-phase layer DAG
# ---------------------------------------------------------------------------
def build_decode_layer_dag(
    cfg: ModelConfig,
    hw: HardwareProfile,
    plan: Plan,
    ctx: int,
    kind: str,
    ffn: str,
    mesh_shape: Optional[Tuple[int, int]] = None,
) -> JobDag:
    dag = JobDag()
    B = plan.B
    miss = _miss_fractions(cfg, plan)
    # expert-parallel mesh (dp, ep): one replica's DAG with experts sharded
    # E/ep per rank — ranks run their local experts concurrently, so the
    # gpu channel only serializes ONE rank's expert share, and an a2a
    # exchange precedes the expert GEMMs (distributed.ep_engine)
    ep = max(1, mesh_shape[1]) if mesh_shape else 1

    # ---- sequence mixer ----
    if kind == "attn":
        w_bytes = W.attn_weight_bytes(cfg) * miss["attn"]
        cp_w = dag.add("attn_weights_htod", "htod", w_bytes / hw.htod_bw)
        n_gpu = int(round(B * (1.0 - plan.omega)))
        n_cpu = B - n_gpu
        pre = dag.add(
            "pre_attn",
            "gpu",
            hw.gemm_time(
                B * W.pre_attn_flops(cfg),
                0.0,
                B * 3 * cfg.d_model * W.BYTES,
                B,
            ),
            deps=[cp_w],
        )
        done_attn: List[int] = []
        if n_cpu:
            qd = dag.add(
                "qkv_dtoh",
                "dtoh",
                n_cpu * 3 * cfg.num_heads * cfg.head_dim * W.BYTES / hw.dtoh_bw,
                deps=[pre],
            )
            cpu = dag.add(
                "cpu_self_attn",
                "cpu",
                hw.cpu_attn_time(
                    n_cpu * W.attn_mech_flops_decode(cfg, ctx),
                    n_cpu * ctx * W.kv_bytes_per_token_layer(cfg),
                ),
                deps=[qd],
            )
            back = dag.add(
                "attn_out_htod",
                "htod",
                n_cpu * cfg.num_heads * cfg.head_dim * W.BYTES / hw.htod_bw,
                deps=[cpu],
            )
            done_attn.append(back)
        if n_gpu:
            b_a = max(1, min(plan.b_a, n_gpu))
            n_micro = -(-n_gpu // b_a)
            span = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
            for m in range(n_micro):
                rows = min(b_a, n_gpu - m * b_a)
                kv_bytes = rows * span * W.kv_bytes_per_token_layer(cfg)
                deps = [pre]
                if not plan.kv_on_gpu:
                    deps.append(
                        dag.add(f"kv_fetch[{m}]", "htod", kv_bytes / hw.htod_bw)
                    )
                g = dag.add(
                    f"gpu_self_attn[{m}]",
                    "gpu",
                    hw.gemm_time(
                        rows * W.attn_mech_flops_decode(cfg, ctx),
                        0.0,
                        kv_bytes,
                        rows,
                    ),
                    deps=deps,
                )
                done_attn.append(g)
        post = dag.add(
            "post_attn",
            "gpu",
            hw.gemm_time(
                B * W.post_attn_flops(cfg), 0.0,
                B * 2 * cfg.d_model * W.BYTES, B,
            ),
            deps=done_attn or [pre],
        )
        dag.add(
            "kv_append_dtoh",
            "dtoh",
            B * W.kv_bytes_per_token_layer(cfg) / hw.dtoh_bw,
            deps=[post],
        )
        mixer_done = post
    else:  # SSM layer: dense module, state stays on device/host
        w_bytes = W.ssm_weight_bytes(cfg) * miss["ssm"]
        cp_w = dag.add("ssm_weights_htod", "htod", w_bytes / hw.htod_bw)
        mixer_done = dag.add(
            "ssm_step",
            "gpu",
            hw.gemm_time(
                B * W.ssm_flops_per_token(cfg),
                0.0,
                B * 4 * cfg.d_model * W.BYTES,
                B,
            ),
            deps=[cp_w],
        )

    # ---- FFN stage ----
    if ffn == "moe":
        router = dag.add(
            "router",
            "gpu",
            hw.gemm_time(B * W.router_flops(cfg), 0.0, 0.0, B),
            deps=[mixer_done],
        )
        tokens_per_expert = B * cfg.experts_per_token / cfg.num_experts
        # grouped dispatch: one launch per expert's share of the (E, C, D)
        # buffer — no b_e chunk loop (engine §4.2 path).  Padded capacity
        # slots cost FLOPs too, so a plan with a real capacity constraint
        # (cap < B) is charged for all cap rows; cap >= B means no buffer
        # constraint and degenerates to gather-exact execution (the loop /
        # baseline systems), charged for the routed tokens only.
        cap = max(1, min(plan.b_e, B))
        rows = float(cap) if cap < B else tokens_per_expert
        e_bytes = W.expert_weight_bytes(cfg) * miss["moe"]
        # predictive per-expert prefetch: only ~k-hat experts move per
        # streamed MoE layer (the predicted set; hits cost nothing extra,
        # mispredictions swap one expert for another — expected traffic is
        # the predicted-set size either way), so the per-expert htod charge
        # scales by k-hat/E instead of each expert paying its full miss
        if plan.predict_topk and cfg.num_experts:
            e_bytes *= min(1.0, plan.predict_topk / cfg.num_experts)
        ffn_deps = [router]
        e_local = cfg.num_experts
        if ep > 1:
            # dispatch + return all-to-all: total payload matches
            # distributed.a2a_bytes_per_stage (copies x ranks x (2 rows of
            # activations + routing meta)); with ep_chunks pipeline chunks
            # only the first chunk's exchange is exposed — the rest overlap
            # the previous chunk's expert GEMMs — but every extra chunk
            # pays its own dispatch launch on the critical path
            copies = B * cfg.experts_per_token
            a2a_total = copies * ep * (2 * cfg.d_model * 4 + 4)
            chunks = max(1, plan.ep_chunks)
            exposed = (hw.a2a_time(a2a_total / chunks, ep)
                       + (chunks - 1) * hw.launch_overhead_s)
            ffn_deps.append(dag.add("moe_a2a", "comm", exposed, deps=[router]))
            e_local = max(1, cfg.num_experts // ep)
        for e in range(e_local):
            cp = dag.add(f"expert_w[{e}]", "htod", e_bytes / hw.htod_bw)
            dag.add(
                f"expert[{e}]",
                "gpu",
                hw.gemm_time(
                    rows * W.expert_flops_per_token(cfg),
                    0.0,
                    rows * 2 * cfg.d_model * W.BYTES,
                    int(max(rows, 1)),
                ),
                deps=[cp] + ffn_deps,
            )
    elif cfg.d_ff > 0:
        w_bytes = W.dense_ffn_weight_bytes(cfg) * miss["dense"]
        cp = dag.add("ffn_w_htod", "htod", w_bytes / hw.htod_bw)
        dag.add(
            "dense_ffn",
            "gpu",
            hw.gemm_time(
                B * W.dense_ffn_flops(cfg),
                0.0,
                B * 2 * cfg.d_model * W.BYTES,
                B,
            ),
            deps=[cp, mixer_done],
        )
    return dag


# ---------------------------------------------------------------------------
# Prefill-phase layer DAG (no KV fetch; GPU-only compute — paper §5.3)
# ---------------------------------------------------------------------------
def build_prefill_layer_dag(
    cfg: ModelConfig,
    hw: HardwareProfile,
    plan: Plan,
    seq: int,
    kind: str,
    ffn: str,
) -> JobDag:
    dag = JobDag()
    B = plan.B
    T = B * seq
    miss = _miss_fractions(cfg, plan)

    if kind == "attn":
        w_bytes = W.attn_weight_bytes(cfg) * miss["attn"]
        cp_w = dag.add("attn_weights_htod", "htod", w_bytes / hw.htod_bw)
        b_a = max(1, min(plan.b_a, B))
        n_micro = -(-B // b_a)
        outs = []
        for m in range(n_micro):
            rows = min(b_a, B - m * b_a)
            g = dag.add(
                f"attn_block[{m}]",
                "gpu",
                hw.gemm_time(
                    rows * (seq * (W.pre_attn_flops(cfg) + W.post_attn_flops(cfg))
                            + W.attn_mech_flops_prefill(cfg, seq)),
                    0.0,
                    rows * seq * 4 * cfg.d_model * W.BYTES,
                    rows * seq,
                ),
                deps=[cp_w],
            )
            outs.append(g)
        dag.add(
            "kv_append_dtoh",
            "dtoh",
            T * W.kv_bytes_per_token_layer(cfg) / hw.dtoh_bw,
            deps=outs,
        )
        mixer_done = outs[-1]
    else:
        w_bytes = W.ssm_weight_bytes(cfg) * miss["ssm"]
        cp_w = dag.add("ssm_weights_htod", "htod", w_bytes / hw.htod_bw)
        mixer_done = dag.add(
            "ssm_scan",
            "gpu",
            hw.gemm_time(
                T * W.ssm_flops_per_token(cfg),
                0.0,
                T * 4 * cfg.d_model * W.BYTES,
                T,
            ),
            deps=[cp_w],
        )

    if ffn == "moe":
        router = dag.add(
            "router", "gpu",
            hw.gemm_time(T * W.router_flops(cfg), 0.0, 0.0, T),
            deps=[mixer_done],
        )
        tokens_per_expert = T * cfg.experts_per_token / cfg.num_experts
        # capacity rows are computed (zero-padded or not); cap >= T means
        # no capacity constraint (gather-exact), as in the decode DAG
        cap = max(1, min(plan.b_e, T))
        rows = float(cap) if cap < T else tokens_per_expert
        e_bytes = W.expert_weight_bytes(cfg) * miss["moe"]
        for e in range(cfg.num_experts):
            cp = dag.add(f"expert_w[{e}]", "htod", e_bytes / hw.htod_bw)
            dag.add(
                f"expert[{e}]",
                "gpu",
                hw.gemm_time(
                    rows * W.expert_flops_per_token(cfg),
                    0.0,
                    rows * 2 * cfg.d_model * W.BYTES,
                    int(max(rows, 1)),
                ),
                deps=[cp, router],
            )
    elif cfg.d_ff > 0:
        w_bytes = W.dense_ffn_weight_bytes(cfg) * miss["dense"]
        cp = dag.add("ffn_w_htod", "htod", w_bytes / hw.htod_bw)
        dag.add(
            "dense_ffn",
            "gpu",
            hw.gemm_time(
                T * W.dense_ffn_flops(cfg),
                0.0,
                T * 2 * cfg.d_model * W.BYTES,
                T,
            ),
            deps=[cp, mixer_done],
        )
    return dag


# ---------------------------------------------------------------------------
# Model-level estimates
# ---------------------------------------------------------------------------
def _layer_types(cfg: ModelConfig) -> Dict[Tuple[str, str], int]:
    types: Dict[Tuple[str, str], int] = {}
    for i in range(cfg.num_layers):
        key = (cfg.layer_kind(i), cfg.ffn_kind(i))
        types[key] = types.get(key, 0) + 1
    return types


def estimate_decode(
    cfg: ModelConfig, hw: HardwareProfile, plan: Plan, ctx: int,
    mesh_shape: Optional[Tuple[int, int]] = None,
) -> PhaseEstimate:
    t_model = 0.0
    htod = dtoh = 0.0
    layer_times: Dict[str, float] = {}
    critical: List[str] = []
    for (kind, ffn), count in _layer_types(cfg).items():
        dag = build_decode_layer_dag(cfg, hw, plan, ctx, kind, ffn,
                                     mesh_shape=mesh_shape)
        t = dag.earliest_finish()
        layer_times[f"{kind}+{ffn}"] = t
        t_model += t * count
        busy = dag.channel_busy()
        htod += busy["htod"] * hw.htod_bw * count
        dtoh += busy["dtoh"] * hw.dtoh_bw * count
        if not critical:
            critical = dag.critical_path()
    # lm_head (+ final norm) on device
    t_model += hw.gemm_time(
        plan.B * W.lm_head_flops(cfg), 0.0,
        plan.B * cfg.vocab_size * W.BYTES, plan.B,
    )
    tp = plan.B / t_model if t_model > 0 else 0.0
    return PhaseEstimate(tp, t_model, plan.B, htod, dtoh, layer_times, critical)


def estimate_prefill(
    cfg: ModelConfig, hw: HardwareProfile, plan: Plan, seq: int
) -> PhaseEstimate:
    t_model = 0.0
    htod = dtoh = 0.0
    layer_times: Dict[str, float] = {}
    critical: List[str] = []
    for (kind, ffn), count in _layer_types(cfg).items():
        dag = build_prefill_layer_dag(cfg, hw, plan, seq, kind, ffn)
        t = dag.earliest_finish()
        layer_times[f"{kind}+{ffn}"] = t
        t_model += t * count
        busy = dag.channel_busy()
        htod += busy["htod"] * hw.htod_bw * count
        dtoh += busy["dtoh"] * hw.dtoh_bw * count
        if not critical:
            critical = dag.critical_path()
    tokens = plan.B * seq
    t_model += hw.gemm_time(
        plan.B * W.lm_head_flops(cfg), 0.0,
        plan.B * cfg.vocab_size * W.BYTES, plan.B,
    )
    tp = tokens / t_model if t_model > 0 else 0.0
    return PhaseEstimate(tp, t_model, tokens, htod, dtoh, layer_times, critical)
