"""Host-side attention path (the paper's AVX CPU kernel, §4.2 + §B).

On a TPU host this computation runs on the host CPU where the offloaded
KV-cache lives, saving HtoD bandwidth for expert prefetch.  The paper's
numerical-consistency scheme (§B) is reproduced exactly: BF16 operands are
represented in FP32 with trailing mantissa bits zeroed, accumulation happens
in FP32, and each dot-product result is rounded back to BF16.

With the paged tiered cache (``serving.cache``) the ω split decides only
the MATH placement — which rows' attention runs through this module —
while ``KVPageTable`` decides where their KV BYTES live: ω host rows
prefer host-tier page frames (``ensure_rows(prefer_host=...)``) so their
pages are read host-side without a DtoH copy, but either tier can spill
into the other, and ``ModuleBatchingEngine._paged_attention_stage``
assembles whatever placement resulted.  Keeping math and storage
independent is what preserves bit-identity with the contiguous cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def round_bf16(x: jax.Array) -> jax.Array:
    """FP32 value with BF16 precision (round-to-nearest-even via cast)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def host_decode_attention(
    q: jax.Array,        # (B, H, D)    bf16 or f32
    k_cache: jax.Array,  # (B, S, K, D)
    v_cache: jax.Array,  # (B, S, K, D)
    pos,                 # scalar or (B,) int: current position (attend <= pos)
) -> jax.Array:
    """Decode-step GQA with the paper's BF16-consistent FP32 arithmetic.

    ``pos`` may be per-sequence (ragged batches): each row attends its own
    ``<= pos`` prefix of the cache.
    """
    B, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    qf = round_bf16(q.astype(jnp.float32)).reshape(B, K, G, D)
    kf = round_bf16(k_cache.astype(jnp.float32))
    vf = round_bf16(v_cache.astype(jnp.float32))
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * (D ** -0.5)
    scores = round_bf16(scores)                       # §B: round after dot
    posv = jnp.atleast_1d(jnp.asarray(pos, jnp.int32))      # (1,) or (B,)
    valid = jnp.arange(k_cache.shape[1])[None, :] <= posv[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", round_bf16(probs), vf)
    return round_bf16(out).reshape(B, H, D)
