"""Cost models of the baseline systems the paper compares against (§3, §5).

All baselines use *model-based batching*: one unified batch size through the
whole forward pass, with the KV-cache resident in device memory (which is
what bounds their batch).  They differ in fetch scheduling:

* ``deepspeed``      — on-demand weight fetch, no compute/copy overlap
                        (DeepSpeed-Inference offloading).
* ``flexgen``        — fetched weights reused across several rounds of
                        micro-batches whose KV lives in host memory;
                        partial overlap.
* ``moe-lightning``  — same batching, full GPU-CPU-I/O overlap (their
                        HRM pipeline) + weight reuse.
* ``vllm``           — continuous batching: decode batch additionally
                        degraded by interleaved size-1 prefills (the paper's
                        observation that TTFT-oriented scheduling shrinks
                        decode batches).

These reproduce the *mechanisms* the paper attributes to each system, not
vendor-tuned kernels; EXPERIMENTS.md compares the resulting ratios against
the paper's Tables 1/4/6/7/8/9.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag_builder import (
    PhaseEstimate,
    Plan,
    build_decode_layer_dag,
    build_prefill_layer_dag,
    _layer_types,
)
from repro.core.hardware import HardwareProfile

SYSTEMS = ("deepspeed", "flexgen", "moe-lightning", "vllm")


def model_based_batch_limit(cfg: ModelConfig, hw: HardwareProfile, ctx: int) -> int:
    """Unified batch bounded by device-resident KV + attention peak memory."""
    per_seq = W.kv_bytes_per_seq(cfg, ctx)
    overhead = W.dense_module_bytes_per_layer(cfg)
    if cfg.has_moe:
        overhead += cfg.num_experts * W.expert_weight_bytes(cfg) / cfg.num_layers
    free = hw.device_mem_bytes * 0.8 - overhead
    if per_seq <= 0:
        per_seq = 4 * cfg.d_model * W.BYTES
    # attention intermediate states also scale with B (paper §5.3: DeepSpeed
    # batch bounded by attention peak memory)
    per_seq += W.intermediate_bytes_decode(cfg, 1, ctx)
    return max(1, int(free / per_seq))


def _combine(cfg, hw, plan, ctx, phase, system, seq=None) -> PhaseEstimate:
    t_model = 0.0
    htod = dtoh = 0.0
    layer_times: Dict[str, float] = {}
    for (kind, ffn), count in _layer_types(cfg).items():
        if phase == "decode":
            dag = build_decode_layer_dag(cfg, hw, plan, ctx, kind, ffn)
        else:
            dag = build_prefill_layer_dag(cfg, hw, plan, seq, kind, ffn)
        busy = dag.channel_busy()
        if system == "deepspeed":
            # on-demand, serialized copy -> compute
            t = busy["gpu"] + busy["htod"] + busy["dtoh"] + busy["cpu"]
        elif system == "flexgen":
            # partial overlap: half the copy hidden behind compute
            t = max(busy["gpu"], busy["htod"]) + 0.5 * min(
                busy["gpu"], busy["htod"]
            ) + busy["dtoh"]
        else:  # moe-lightning, vllm: fully pipelined channels
            t = max(busy["gpu"], busy["htod"], busy["cpu"]) + busy["dtoh"]
        layer_times[f"{kind}+{ffn}"] = t
        t_model += t * count
        htod += busy["htod"] * hw.htod_bw * count
        dtoh += busy["dtoh"] * hw.dtoh_bw * count
    t_model += hw.gemm_time(
        plan.B * W.lm_head_flops(cfg), 0.0,
        plan.B * cfg.vocab_size * W.BYTES, plan.B,
    )
    tokens = plan.B * (seq if phase == "prefill" else 1)
    return PhaseEstimate(
        tokens / t_model, t_model, tokens, htod, dtoh, layer_times, []
    )


def estimate_baseline_decode(
    cfg: ModelConfig,
    hw: HardwareProfile,
    ctx: int,
    system: str,
    decode_len: int = 256,
) -> PhaseEstimate:
    assert system in SYSTEMS
    B = model_based_batch_limit(cfg, hw, ctx)
    reuse = 1
    if system in ("flexgen", "moe-lightning"):
        # rounds whose KV fits host memory, reusing fetched weights
        host_free = hw.host_mem_bytes - W.model_bytes(cfg)
        per_round = max(B * W.kv_bytes_per_seq(cfg, ctx), 1.0)
        cap = 2 if system == "flexgen" else 4
        reuse = int(max(1, min(cap, host_free / per_round)))
    plan = Plan(
        B=B, b_a=B, b_e=1 << 30, omega=0.0,
        s_expert=0.0, s_params=0.0, phase="decode",
        kv_on_gpu=True, weight_reuse=reuse,
    )
    est = _combine(cfg, hw, plan, ctx, "decode", system)
    if system == "vllm":
        # continuous batching: each finished sequence triggers a size-1
        # prefill that stalls decode (paper §3: prefill batches of size 1)
        t_prefill_1 = _combine(
            cfg, hw,
            Plan(B=1, b_a=1, b_e=1 << 30, phase="prefill", kv_on_gpu=True),
            ctx, "prefill", "moe-lightning", seq=ctx,
        ).t_model
        stall_per_step = (B / max(decode_len, 1)) * t_prefill_1 / max(B, 1)
        t = est.t_model + stall_per_step * B
        est = PhaseEstimate(
            est.tokens / t, t, est.tokens, est.htod_bytes, est.dtoh_bytes,
            est.layer_times, [],
        )
    return est


def estimate_baseline_prefill(
    cfg: ModelConfig, hw: HardwareProfile, seq: int, system: str
) -> PhaseEstimate:
    assert system in SYSTEMS
    B = model_based_batch_limit(cfg, hw, seq)
    plan = Plan(
        B=B, b_a=B, b_e=1 << 30, phase="prefill",
        kv_on_gpu=True, weight_reuse=1,
    )
    return _combine(cfg, hw, plan, seq, "prefill", system, seq=seq)
