"""Hardware profiles for the batching planner and the roofline analysis.

The paper's testbeds (Table 3) are modeled with published A5000/A6000 specs
plus the PCIe 4.0 link the paper states (32 GB/s).  The TPU v5e profile uses
the constants mandated for the roofline analysis: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI, and a host link comparable to PCIe 4.0.

``matmul_utilization`` models the empirically observed ramp of achieved
FLOPs with per-module batch size (paper Fig. 3 left: ~2^10 tokens required
to saturate): a tile-quantization ramp that saturates at
``saturation_tokens``.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    # accelerator
    device_flops: float            # peak dense matmul FLOP/s (bf16)
    device_mem_bw: float           # HBM bytes/s
    device_mem_bytes: float        # HBM capacity
    saturation_tokens: int         # per-module batch needed for full util
    # host
    host_mem_bytes: float
    cpu_flops: float               # effective host matmul FLOP/s
    cpu_mem_bw: float              # host DRAM bytes/s (bounds host GEMV)
    cpu_cores: int = 16
    # links
    htod_bw: float = 32e9          # host -> device bytes/s
    dtoh_bw: float = 32e9          # device -> host bytes/s
    ici_bw: float = 0.0            # inter-chip bytes/s per link (TPU)
    launch_overhead_s: float = 20e-6   # per-module launch overhead

    def matmul_utilization(self, tokens: int) -> float:
        """Fraction of peak FLOPs achieved by a GEMM over `tokens` rows."""
        if tokens <= 0:
            return 1e-6
        # linear ramp to saturation, floored at the single-tile rate
        return min(1.0, max(tokens, 8) / self.saturation_tokens)

    def gemm_time(self, flops: float, weight_bytes: float, act_bytes: float,
                  tokens: int) -> float:
        """Roofline GEMM time with the utilization ramp."""
        compute = flops / (self.device_flops * self.matmul_utilization(tokens))
        memory = (weight_bytes + act_bytes) / self.device_mem_bw
        return max(compute, memory) + self.launch_overhead_s

    def cpu_attn_time(self, flops: float, kv_bytes: float) -> float:
        """Host attention (GEMV-dominated => bandwidth bound)."""
        return max(flops / self.cpu_flops, kv_bytes / self.cpu_mem_bw)

    def a2a_time(self, nbytes: float, n_ranks: int) -> float:
        """All-to-all exchange time over ``n_ranks`` expert-parallel ranks.

        ``nbytes`` is the TOTAL payload of the exchange (both directions
        summed, as reported by ``distributed.a2a_bytes_per_stage``).  Each
        rank keeps 1/n of its sends local, so only the (n-1)/n fraction
        crosses the link; the link is the ICI where profiled, else the
        host-interconnect (multi-GPU boxes exchange over PCIe/NVLink
        modeled at the host-link rate).
        """
        if n_ranks <= 1 or nbytes <= 0:
            return 0.0
        bw = self.ici_bw or self.htod_bw
        wire = nbytes * (n_ranks - 1) / n_ranks
        return wire / bw + self.launch_overhead_s


# --------------------------------------------------------------------------
# Paper testbeds (Table 3)
# --------------------------------------------------------------------------
A5000_C1 = HardwareProfile(
    name="C1-A5000-256GB",
    device_flops=27.8e12 * 2,      # fp16/bf16 tensor-core dense
    device_mem_bw=768e9,
    device_mem_bytes=24e9,
    saturation_tokens=1024,        # paper Fig. 3 left
    host_mem_bytes=256e9,
    cpu_flops=1.2e12,              # AMD 7453 28C AVX2
    cpu_mem_bw=60e9,               # achieved AVX attention-kernel bandwidth
    cpu_cores=28,
    htod_bw=32e9,
    dtoh_bw=32e9,
)

A5000_C2 = HardwareProfile(
    name="C2-A5000-512GB",
    device_flops=27.8e12 * 2,
    device_mem_bw=768e9,
    device_mem_bytes=24e9,
    saturation_tokens=1024,
    host_mem_bytes=512e9,
    cpu_flops=1.2e12,
    cpu_mem_bw=60e9,
    cpu_cores=28,
    htod_bw=32e9,
    dtoh_bw=32e9,
)

A6000_C3 = HardwareProfile(
    name="C3-A6000-480GB",
    device_flops=38.7e12 * 2,
    device_mem_bw=768e9,
    device_mem_bytes=48e9,
    saturation_tokens=1024,
    host_mem_bytes=480e9,
    cpu_flops=0.6e12,              # AMD 7313P 16C — weaker host
    cpu_mem_bw=30e9,
    cpu_cores=16,
    htod_bw=32e9,
    dtoh_bw=32e9,
)

TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    device_flops=197e12,
    device_mem_bw=819e9,
    device_mem_bytes=16e9,
    saturation_tokens=1024,
    host_mem_bytes=512e9,
    cpu_flops=1.5e12,
    cpu_mem_bw=150e9,
    cpu_cores=112,
    htod_bw=32e9,
    dtoh_bw=32e9,
    ici_bw=50e9,
)

PROFILES = {p.name: p for p in (A5000_C1, A5000_C2, A6000_C3, TPU_V5E)}
