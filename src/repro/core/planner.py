"""Batching-strategy search (paper §4.3–4.4, Eq. 1–3).

Enumerates candidate configurations over the Table-2 variables
(B, b_a, b_e, ω, S_Expert, S_Params), discards those violating the host
(Eq. 2) and device (Eq. 3) memory constraints, estimates each survivor's
runtime with the DAG critical-path model, and returns the throughput-
maximizing plan.  Prefill and decode are searched separately
(P-D disaggregation); following the paper, decode fixes B to the host-memory
maximum.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag_builder import (
    PhaseEstimate,
    Plan,
    estimate_decode,
    estimate_prefill,
)
from repro.core.hardware import HardwareProfile


# ---------------------------------------------------------------------------
# Constraints
# ---------------------------------------------------------------------------
def host_batch_limit(cfg: ModelConfig, hw: HardwareProfile, ctx: int) -> int:
    """Eq. 2: S_KV-CPU(B) + S_Model <= m_c."""
    free = hw.host_mem_bytes - W.model_bytes(cfg)
    if free <= 0:
        return 0
    per_seq = W.kv_bytes_per_seq(cfg, ctx)
    if per_seq <= 0:
        return 1 << 20                      # SSM: state is tiny
    return max(0, int(free / per_seq))


def host_kv_budget(cfg: ModelConfig, hw: HardwareProfile) -> float:
    """Eq. 2's free host bytes for offloaded KV/state: m_c - S_Model
    (clamped at 0).  The continuous scheduler admits a request only while
    the KV bytes of every in-flight sequence (at its full prompt+decode
    extent) fit here."""
    return max(0.0, hw.host_mem_bytes - W.model_bytes(cfg))


def select_residency(
    cfg: ModelConfig, hw: HardwareProfile, plan: Plan, ctx: int, phase: str
) -> Optional[Plan]:
    """Realize S_Params/S_Expert for a candidate plan (Table 2 -> policy).

    ``s_params``/``s_expert`` are no longer free variables of the estimate:
    given the non-weight device footprint of Eq. 3, either the whole model
    fits in the spare bytes (fully resident, no stream buffer) or the spare
    is split into a double-buffered stream window
    (``workload.stream_buffer_bytes``) plus a greedily-filled resident set
    (``workload.plan_residency`` — the exact set the executor's ParamStore
    pins).  Returns None when not even the always-resident base weights and
    one stream window fit.

    ``plan.predict_topk > 0`` sizes the stream-window slot by the EXPECTED
    predicted-expert set (k-hat experts per MoE layer) instead of the
    worst-layer whole stack — the bytes that frees are greedily re-pinned
    by ``plan_residency`` as extra resident modules, and whatever the
    greedy fill still leaves over becomes the store's hot-expert LRU
    budget (``ResidencyPlan.spare_bytes``).
    """
    footprint = device_memory_used(
        cfg, replace(plan, s_params=0.0, s_expert=0.0), ctx, phase
    )
    spare = hw.device_mem_bytes - footprint
    if spare <= 0:
        return None
    mb = W.model_bytes(cfg)
    if mb <= spare:
        return replace(plan, s_params=float(mb), s_expert=0.0)
    s_expert = W.stream_buffer_bytes(
        cfg, depth=2, predict_topk=getattr(plan, "predict_topk", 0)
    )
    rp = W.plan_residency(cfg, spare - s_expert)
    if rp.resident_bytes + s_expert > spare:
        return None                         # base weights + window don't fit
    return replace(plan, s_params=rp.resident_bytes, s_expert=s_expert)


def default_predict_topk(cfg: ModelConfig) -> int:
    """Default predicted-set size k-hat for predictive expert streaming:
    twice the routed top-k (headroom for batch diversity — different rows
    route to different experts), clamped to the expert count.  0 for
    non-MoE configs (prediction is meaningless without experts)."""
    if not cfg.has_moe:
        return 0
    return min(cfg.num_experts, max(2, 2 * cfg.experts_per_token))


def capacity_for_load(
    load: Iterable[float], B: int, k: int, max_drop_rate: float = 0.0
) -> int:
    """Smallest per-expert capacity ``b_e`` whose EXPECTED drop rate under
    the measured routing distribution stays within ``max_drop_rate``.

    ``load`` is a per-expert routed-copy histogram (the device-side
    accumulation ``EngineStats.expert_load`` drains — any non-negative
    weights work; only the shares matter).  A decode step routes ``B * k``
    copies; expert *e* expects ``n_e = B * k * share_e`` of them and drops
    ``max(0, n_e - C)`` beyond capacity ``C``.  This replaces the uniform-
    routing assumption of the a-priori ``b_e`` grid: under skew the hot
    expert's share — not ``k/E`` — is what sizes the dispatch buffer.

    Binary-searches C in ``[1, B]`` (a single expert can receive at most
    one copy per token).  ``max_drop_rate=0`` returns the zero-expected-
    drop capacity, i.e. the measured-max expert share of a step."""
    shares = [max(0.0, float(x)) for x in load]
    total = sum(shares)
    copies = float(max(1, B) * max(1, k))
    if total <= 0.0:
        return max(1, min(B, -(-int(copies) // max(1, len(shares) or 1))))
    exp = [s / total * copies for s in shares]
    budget = max_drop_rate * copies

    def dropped(C: int) -> float:
        return sum(max(0.0, n - C) for n in exp)

    lo, hi = 1, max(1, B)
    while lo < hi:
        mid = (lo + hi) // 2
        if dropped(mid) <= budget:
            hi = mid
        else:
            lo = mid + 1
    return lo


def select_decode_chunk(
    plan: Plan,
    mean_decode_len: int,
    scheduler: str = "continuous",
    arrival_rate: float = 0.0,
    step_time_s: Optional[float] = None,
    cap: int = 64,
) -> int:
    """Plan the fused decode chunk ``T`` from the admission cadence.

    The fused engine generates ``T`` tokens per device dispatch, but the
    scheduler can only admit/evict at chunk boundaries — so ``T`` must stay
    below the expected number of decode ticks between scheduling events:

    * ``continuous`` — a slot frees roughly every ``mean_decode_len / B``
      ticks (evictions are the admission opportunities);
    * ``static`` — nothing is admitted mid-wave, so the cadence is the wave
      itself (``mean_decode_len`` ticks);
    * an open-loop arrival stream at ``arrival_rate`` req/s delivers a new
      request every ``1 / (rate * step_time_s)`` ticks (when ``step_time_s``
      is known, e.g. from the DAG estimate's ``t_model``).

    Returns the largest power of two no larger than the tightest cadence,
    clamped to ``[1, cap]``.  ``T`` only affects scheduling granularity,
    never tokens — the engine's fused chunk is token-identical to per-tick
    decode at any ``T``.
    """
    if scheduler == "static":
        cadence = float(max(1, mean_decode_len))
    else:
        cadence = mean_decode_len / max(1, plan.B)
    if arrival_rate > 0 and step_time_s:
        cadence = min(cadence, 1.0 / (arrival_rate * step_time_s))
    T = 1
    while T * 2 <= min(cadence, float(cap)):
        T *= 2
    return T


def device_memory_used(
    cfg: ModelConfig, plan: Plan, ctx: int, phase: str
) -> float:
    """LHS of Eq. 3."""
    s_dense = W.dense_module_bytes_per_layer(cfg)
    kv_gpu = plan.b_a * min(ctx, cfg.sliding_window or ctx) * \
        W.kv_bytes_per_token_layer(cfg) if cfg.has_attention else 0.0
    if phase == "decode":
        s_is = W.intermediate_bytes_decode(cfg, plan.b_a, ctx)
    else:
        s_is = W.intermediate_bytes_prefill(cfg, plan.b_a, ctx)
    # accumulated hidden states for the expert stage + the grouped-dispatch
    # (E, C, D) capacity buffer.  At decode C = b_e (clamped to the tokens
    # that exist); at prefill the engine sizes C to the next power-of-two
    # bucket over the micro-batch's MEASURED per-expert routed load (zero
    # drops still guaranteed — the bucket is >= the max load), so Eq. 3
    # charges the expected bucket: the balanced per-expert share with the
    # config's capacity-factor headroom, pow2-rounded, capped at the full
    # micro-batch token count (the worst-case bucket under total skew).
    tokens = plan.B * (ctx if phase == "prefill" else 1)
    s_is += tokens * 2 * cfg.d_model * W.BYTES
    if cfg.has_moe:
        if phase == "prefill":
            mb_tokens = max(1, min(plan.b_a * ctx, tokens))
            per_e = -(-mb_tokens * cfg.experts_per_token
                      // max(cfg.num_experts, 1))
            cap = min(mb_tokens,
                      W.next_pow2(int(per_e * cfg.capacity_factor) + 1))
        else:
            cap = max(1, min(plan.b_e, tokens))
        s_is += W.expert_buffer_bytes(cfg, cap)
    # paged KV: the device page pool (+1 null write-sink frame) is a
    # standing Eq. 3 charge on top of the per-launch gather working set
    kv_pool = 0.0
    if plan.kv_page_tokens > 0 and plan.kv_device_pages > 0:
        kv_pool = (plan.kv_device_pages + 1) * W.kv_page_frame_bytes(
            cfg, plan.kv_page_tokens
        )
    return plan.s_params + plan.s_expert + s_dense + kv_gpu + s_is + kv_pool


def device_memory_ok(
    cfg: ModelConfig, hw: HardwareProfile, plan: Plan, ctx: int, phase: str
) -> bool:
    return device_memory_used(cfg, plan, ctx, phase) <= hw.device_mem_bytes


def kv_device_pool_frames(
    cfg: ModelConfig, hw: HardwareProfile, plan: Plan, ctx: int,
    page_tokens: int,
) -> int:
    """Size the paged KV device pool from the Eq. 3 spare: how many page
    frames fit on device AFTER the plan's weights, stream window, dispatch
    buffers and activations are charged.  The remainder of the batch's
    frames live on the host tier (Mode B — streamed like expert weights).
    Returns 0 when nothing is spare (every frame host-side)."""
    assert page_tokens > 0
    base = replace(plan, kv_page_tokens=0, kv_device_pages=0)
    spare = hw.device_mem_bytes - device_memory_used(
        cfg, base, ctx, plan.phase
    )
    fb = W.kv_page_frame_bytes(cfg, page_tokens)
    if fb <= 0 or spare <= fb:              # +1 null frame must fit too
        return 0
    return int(spare // fb) - 1


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------
def _pow2_grid(lo: int, hi: int) -> List[int]:
    out, v = [], lo
    while v < hi:
        out.append(v)
        v *= 2
    out.append(hi)
    return sorted(set(out))


@dataclass
class SearchResult:
    plan: Plan
    estimate: PhaseEstimate
    evaluated: int


def search_decode(
    cfg: ModelConfig,
    hw: HardwareProfile,
    ctx: int,
    B: Optional[int] = None,
    omega_grid: Optional[Iterable[float]] = None,
    use_cpu_attention: bool = True,
    decode_len: Optional[int] = None,
    arrival_rate: float = 0.0,
    scheduler: str = "continuous",
    expert_load: Optional[Iterable[float]] = None,
    max_drop_rate: float = 0.01,
    mesh_shape: Optional[Tuple[int, int]] = None,
) -> SearchResult:
    """``expert_load`` (a per-expert routed-copy histogram, e.g. a drained
    ``EngineStats.expert_load`` row or its layer sum) replaces the uniform-
    routing ``b_e`` grid with ``capacity_for_load`` capacities at a few
    drop-rate targets around ``max_drop_rate`` — the measured-skew search.
    Candidates also enumerate ``predict_topk`` in {0, default} so the cost
    model can trade whole-stack streaming against predictive per-expert
    prefetch (smaller stream window, more resident bytes, k-hat experts of
    htod per MoE layer instead of E).

    ``mesh_shape=(dp, ep)`` plans one expert-parallel replica: the decode
    DAG shards experts E/ep per rank with an all-to-all exchange per MoE
    layer (``hw.a2a_time``), and the search additionally picks the
    pipeline chunk count (``plan.ep_chunks`` in {1, 2, 4, 8}) that
    minimizes the exposed a2a time against the per-chunk dispatch
    overhead it buys."""
    B_max = host_batch_limit(cfg, hw, ctx)
    if B_max == 0:
        raise ValueError(f"{cfg.name} does not fit in host memory")
    B = min(B or B_max, B_max)
    if omega_grid is None:
        omega_grid = [i / 10 for i in range(11)] if use_cpu_attention else [0.0]
    # DeepSeek-style latent/up-projected KV makes host attention unprofitable
    # (paper §5.3 sets w=0 for DeepSeek); attention-free archs have no split.
    if not cfg.has_attention:
        omega_grid = [0.0]

    best: Optional[Tuple[float, Plan, PhaseEstimate]] = None
    n_eval = 0
    # B starts at the host-memory maximum (the paper's choice).  Under the
    # REALIZABLE residency policy a plan must also fit its grouped dispatch
    # buffer + stream window + base weights on device — at small contexts
    # the host-max B can make that impossible, so B is halved until a
    # realizable plan exists (the old free-variable search would return
    # plans the engine could not execute).
    B_try = B
    while best is None and B_try >= 1:
        # b_e is the per-expert capacity of the (E, C, D) dispatch buffer:
        # enumerate headroom factors over the balanced per-expert load
        # (never below it — under-provisioning trades dropped tokens for
        # speed, which the throughput objective cannot see), clamped to B
        # (the most tokens one expert can receive per decode step).
        if cfg.has_moe:
            if expert_load is not None:
                # measured-skew capacities: the drop-rate-constrained
                # search over the observed routing distribution, bracketed
                # with zero-drop and a looser target so the throughput
                # objective can trade buffer bytes against drops
                b_e_grid = sorted({
                    capacity_for_load(expert_load, B_try,
                                      cfg.experts_per_token, eps)
                    for eps in (0.0, max_drop_rate, 4 * max_drop_rate)
                })
            else:
                per_e = max(
                    1, -(-B_try * cfg.experts_per_token
                         // max(cfg.num_experts, 1))
                )
                b_e_grid = sorted(
                    {max(1, min(B_try, int(per_e * f)))
                     for f in (1.0, 1.25, 1.5, 2.0)}
                )
            pt_grid = sorted({0, default_predict_topk(cfg)})
        else:
            b_e_grid = [1]
            pt_grid = [0]
        for b_a in _pow2_grid(32, max(32, B_try)):
            for b_e in b_e_grid:
                for omega in omega_grid:
                    for pt in pt_grid:
                        plan = select_residency(
                            cfg, hw,
                            Plan(B=B_try, b_a=b_a, b_e=b_e, omega=omega,
                                 phase="decode", predict_topk=pt),
                            ctx, "decode",
                        )
                        if plan is None or not device_memory_ok(
                            cfg, hw, plan, ctx, "decode"
                        ):
                            continue
                        # prediction only matters when experts stream
                        if pt and W.plan_residency(
                            cfg, plan.s_params
                        ).fully_resident:
                            continue
                        est = estimate_decode(cfg, hw, plan, ctx,
                                              mesh_shape=mesh_shape)
                        n_eval += 1
                        if best is None or est.throughput > best[0]:
                            best = (est.throughput, plan, est)
        B_try //= 2
    assert best is not None, "no feasible decode plan"
    plan, est = best[1], best[2]
    # expert-parallel pipelining: with a mesh, re-estimate the winning plan
    # at each chunk count — more chunks hide more a2a wire time behind the
    # previous chunk's expert GEMMs but pay extra dispatch launches, so the
    # optimum is workload-dependent (EPS-MoE-style schedule search)
    if mesh_shape is not None and mesh_shape[1] > 1 and cfg.has_moe:
        chunk_best: Optional[Tuple[float, Plan, PhaseEstimate]] = None
        for chunks in (1, 2, 4, 8):
            if chunks > max(1, plan.B):
                continue
            cand = replace(plan, ep_chunks=chunks)
            ce = estimate_decode(cfg, hw, cand, ctx, mesh_shape=mesh_shape)
            n_eval += 1
            if chunk_best is None or ce.throughput > chunk_best[0]:
                chunk_best = (ce.throughput, cand, ce)
        if chunk_best is not None:
            plan, est = chunk_best[1], chunk_best[2]
    # realized workload prior for the fused chunk: the caller's mean decode
    # length if known, else a coarse quarter-context default
    mean_dec = decode_len if decode_len else max(1, ctx // 4)
    plan = replace(plan, decode_chunk=select_decode_chunk(
        plan, mean_dec, scheduler=scheduler, arrival_rate=arrival_rate,
        step_time_s=est.t_model,
    ))
    return SearchResult(plan, est, n_eval)


def search_prefill(
    cfg: ModelConfig,
    hw: HardwareProfile,
    seq: int,
    B: Optional[int] = None,
) -> SearchResult:
    B_max = host_batch_limit(cfg, hw, seq)
    B = min(B or B_max, B_max)
    best: Optional[Tuple[float, Plan, PhaseEstimate]] = None
    n_eval = 0
    for B_try in _pow2_grid(8, max(8, B)):
        for b_a in _pow2_grid(1, B_try):
            # prefill capacity: the balanced per-expert share of the B*seq
            # token wave with the config's capacity factor as headroom
            T = B_try * seq
            if cfg.has_moe:
                per_e = T * cfg.experts_per_token / max(cfg.num_experts, 1)
                b_e = max(1, min(T, int(per_e * cfg.capacity_factor) + 1))
            else:
                b_e = 1
            plan = select_residency(
                cfg, hw,
                Plan(B=B_try, b_a=b_a, b_e=b_e, omega=0.0, phase="prefill"),
                seq, "prefill",
            )
            if plan is None or not device_memory_ok(
                cfg, hw, plan, seq, "prefill"
            ):
                continue
            est = estimate_prefill(cfg, hw, plan, seq)
            n_eval += 1
            if best is None or est.throughput > best[0]:
                best = (est.throughput, plan, est)
    assert best is not None, f"no feasible prefill plan for {cfg.name}"
    return SearchResult(best[1], best[2], n_eval)
