"""The MoE-Gen engine: executable module-based batching (paper §4.2).

This is the real thing, not the cost model: given a model's parameters and a
``Plan``, the engine runs generative inference by launching **per-module**
batched computations —

* the attention module consumes micro-batches of ``b_a`` sequences; outputs
  accumulate in host memory until all ``B`` sequences are ready;
* a fraction ``ω`` of each attention batch is computed on the *host* path
  (``core.host_attention``), where the offloaded KV-cache lives;
* the sparse-MoE stage runs as ONE **grouped dispatch**: routed tokens are
  gathered on device into an ``(E, C, D)`` capacity buffer (``C`` = the
  plan's per-expert token budget ``b_e``), pushed through a single grouped
  FFN launch (Pallas on TPU, XLA einsum elsewhere — ``kernels.ops``), and
  scatter-added back weighted by their gates.  Routing indices never leave
  the device, so a decode step issues no host syncs; routed copies beyond
  capacity are dropped and accounted in ``EngineStats``;
* dense modules (SSM blocks, shared FFNs, lm_head) run at full batch.

**Weight residency (the paper's S_Params / S_Expert, Fig. 6).**  Every
module stage pulls its parameters through a ``serving.weights.ParamStore``
handle instead of captured dicts.  By default the store pins everything on
device (``resident_bytes=None``); with ``stream_weights=True`` it realizes
``Plan.s_params`` as a greedy resident set (base embed/head first, then
mixers/norms, then expert stacks — ``workload.plan_residency``, the same
policy the planner's cost model charges misses with) and keeps the rest
host-side, served through a double-buffered in-flight window sized by
``Plan.s_expert``: the engine issues the async htod prefetch of layer
*l+1*'s streamed modules before launching layer *l*'s FFN/grouped GEMM, so
the copy hides behind compute with no host syncs.  Streamed generation is
token-for-token identical to fully-resident generation (property-tested in
tests/test_weights.py); transfer bytes and stall seconds are folded into
``EngineStats`` by ``sync_stats()``.

Prefill shares the layer-major structure: each layer's weights are acquired
ONCE and reused across all ``b_a``-sequence micro-batches (module-based
batching's weight amortization), and the MoE stage runs through the same
grouped dispatch as decode (``grouped_prefill=True``, the default) with the
capacity auto-raised to the micro-batch token count so no routed copy is
ever dropped; ``grouped_prefill=False`` opts prefill back into the exact
dense-combine reference MoE, and ``expert_path='loop'`` opts decode into
the seed's sequential per-expert loop.

Outputs are bit-compatible with the reference ``models.decode_step`` up to
bf16 accumulation order (asserted in tests/test_engine.py).  Every module is
a separately jitted function — the JAX analogue of the paper's per-module
CUDA launches.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dag_builder import Plan
from repro.core.host_attention import host_decode_attention
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import ffn_apply, layer_forward
from repro.models.layers import rms_norm
from repro.serving.weights import ParamStore, unstack_layers  # noqa: F401
from repro.sharding.specs import ShardCtx


# ---------------------------------------------------------------------------
# Jitted module launches
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg",))
def _attn_decode_module(cfg, p, x_mb, k, v, pos):
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    y, cache = attn_mod.attn_decode(cfg, p["attn"], h, {"k": k, "v": v}, pos)
    return y[:, 0], cache["k"], cache["v"]


@functools.partial(jax.jit, static_argnames=("cfg",))
def _attn_decode_host_module(cfg, p, x_mb, k, v, pos):
    """Host-path attention: projections on device, mechanism on host CPU
    with the paper's BF16-consistent arithmetic (§B)."""
    from repro.models.layers import apply_rope

    B = x_mb.shape[0]
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    q, k_new, v_new = attn_mod._project_qkv(cfg, p["attn"], h)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,)
    )                                                       # (B,) ragged-safe
    posb = posv[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    span = k.shape[1]
    slot = jnp.where(cfg.sliding_window > 0, posv % span,
                     jnp.minimum(posv, span - 1))
    rows = jnp.arange(B)
    ck = k.at[rows, slot].set(k_new[:, 0])
    cv = v.at[rows, slot].set(v_new[:, 0])
    out = host_decode_attention(q[:, 0], ck, cv, posv)      # (B, H, D) f32
    o = out.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(x_mb.dtype)
    y = o @ p["attn"]["wo"]
    return y[:, 0], ck, cv


@functools.partial(jax.jit, static_argnames=("cfg",))
def _ssm_decode_module(cfg, p, x, state):
    h = rms_norm(x[:, None, :], p["norm1"], cfg.norm_eps)
    y, state = ssm_mod.ssm_decode(cfg, p["ssm"], h, state)
    return y[:, 0], state


@functools.partial(jax.jit, static_argnames=("cfg",))
def _router_module(cfg, router_w, h):
    return moe_mod.route(cfg, router_w, h)


@jax.jit
def _expert_module(wg, wu, wd, h_chunk):
    """One expert over a chunk of tokens (the 'loop' oracle path's unit)."""
    g = h_chunk @ wg
    u = h_chunk @ wu
    return (jax.nn.silu(g) * u) @ wd


@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _grouped_expert_module(cfg, p, x, capacity):
    """The whole MoE stage as one on-device launch sequence: norm -> route ->
    capacity-bucketed gather -> grouped FFN -> weighted scatter-add.
    Returns (y, kept, dropped); the counters stay on device."""
    moe = p["moe"]
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    gates, idx, _ = moe_mod.route(cfg, moe["router"], h)
    return moe_mod.grouped_dispatch(
        cfg, h, gates, idx,
        moe["experts_w_gate"], moe["experts_w_up"], moe["experts_w_down"],
        capacity,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def _ffn_module(cfg, p, x):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return ffn_apply(p["ffn"], h)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _norm2_module(cfg, p, x):
    return rms_norm(x, p["norm2"], cfg.norm_eps)


@functools.partial(jax.jit, static_argnames=("cfg", "tie"))
def _head_module(cfg, tie, params, x):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if tie else params["lm_head"]
    return h @ w


@functools.partial(jax.jit, static_argnames=("cfg",))
def _embed_module(cfg, embed, tokens):
    return jnp.take(embed, tokens, axis=0)


@functools.partial(jax.jit, static_argnames=("cfg", "kind", "ffn", "sctx"))
def _prefill_layer_module(cfg, kind, ffn, sctx, p, x, positions, lengths):
    """One full layer (mixer + FFN stage) over a prefill micro-batch.

    Prefill's per-layer launch unit: the engine iterates layers in the
    outer loop (weights acquired once per layer, reused by every
    micro-batch) and micro-batches in the inner loop.  ``sctx`` selects the
    MoE path — grouped prefill passes ``moe_capacity`` = the micro-batch
    token count, so no routed copy is dropped."""
    return layer_forward(cfg, kind, ffn, p, x, sctx, positions, lengths)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclass
class EngineStats:
    attn_microbatches: int = 0
    expert_launches: int = 0             # grouped: one per MoE layer per step
    expert_tokens: int = 0               # routed token-copies processed
    expert_tokens_dropped: int = 0       # routed copies over the b_e capacity
    host_attn_tokens: int = 0
    device_attn_tokens: int = 0
    weight_htod_bytes: int = 0           # streamed weight bytes copied htod
    prefetch_wait_s: float = 0.0         # stall waiting on weight transfers


class ModuleBatchingEngine:
    """Executes a batching ``Plan`` over a real model.

    ``expert_path`` selects the MoE stage implementation:

    * ``'grouped'`` (default) — one jitted grouped-dispatch launch per MoE
      layer; routing stays on device, ``plan.b_e`` is the per-expert token
      capacity ``C`` of the ``(E, C, D)`` dispatch buffer.  Prefill shares
      the same grouped implementation (``grouped_prefill=True``, the
      default) with the capacity auto-raised to the micro-batch token count
      (never below, so zero ``expert_tokens_dropped`` at prefill by
      construction); pass ``grouped_prefill=False`` for the exact-reference
      dense-combine prefill.
    * ``'loop'`` — the seed's host-scheduled sequential per-expert loop,
      kept as the numerical oracle (syncs routing to host every step).

    ``grouped_prefill`` is independent of ``expert_path`` (prefill and
    decode paths are selected separately), so a loop-decode engine still
    shares the grouped prefill numerics by default and grouped-vs-loop
    generation stays token-for-token comparable.

    **Weight residency.**  All module stages read parameters through
    ``self.store`` (a ``serving.weights.ParamStore``).  By default every
    weight is device-resident.  ``stream_weights=True`` keeps only the plan's
    ``s_params`` greedy resident set on device and streams the rest from
    host through a double-buffered async prefetch window (``prefetch=False``
    degrades to serialized on-demand fetches); ``resident_bytes`` overrides
    the budget.  A pre-built ``store`` can be passed directly.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        plan: Plan,
        max_seq: int = 512,
        expert_path: str = "grouped",
        grouped_prefill: bool = True,
        store: Optional[ParamStore] = None,
        stream_weights: bool = False,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
    ) -> None:
        assert expert_path in ("grouped", "loop"), expert_path
        self.cfg = cfg
        self.plan = plan
        self.max_seq = max_seq
        self.expert_path = expert_path
        self.grouped_prefill = grouped_prefill
        if store is None:
            store = ParamStore.build(
                cfg, params, plan, stream_weights=stream_weights,
                resident_bytes=resident_bytes, prefetch=prefetch,
            )
        self.store = store
        self.schema = store.schema                  # [(kind, ffn)] per layer
        # kept for introspection/back-compat: (kind, ffn, _) triples
        self.layers: List[Tuple[str, str, None]] = [
            (k, f, None) for k, f in self.schema
        ]
        self.cache: Optional[List] = None
        self.stats = EngineStats()
        # device-side counters, folded into `stats` by sync_stats(); keeping
        # them lazy is what lets decode_step run without a single host sync.
        self._kept_dev = jnp.zeros((), jnp.int32)
        self._dropped_dev = jnp.zeros((), jnp.int32)

    def _expert_capacity(self, batch: int) -> int:
        """Per-expert capacity C: the plan's b_e, clamped to the most tokens
        any one expert can receive (top-k indices are distinct per token)."""
        return max(1, min(self.plan.b_e, batch))

    def sync_stats(self) -> EngineStats:
        """Materialize the device-side expert counters (one host sync) and
        drain the store's transfer accounting."""
        self.stats.expert_tokens += int(self._kept_dev)
        self.stats.expert_tokens_dropped += int(self._dropped_dev)
        self._kept_dev = jnp.zeros((), jnp.int32)
        self._dropped_dev = jnp.zeros((), jnp.int32)
        htod, wait = self.store.take_counters()
        self.stats.weight_htod_bytes += htod
        self.stats.prefetch_wait_s += wait
        return self.stats

    # -- cache management ---------------------------------------------
    def init_cache(self, batch: int) -> None:
        self.cache = []
        for kind, _ in self.schema:
            from repro.models.blocks import init_layer_cache

            self.cache.append(init_layer_cache(self.cfg, kind, batch, self.max_seq))

    def _write_cache_rows(self, li: int, kind: str, entry: Dict, rows) -> None:
        """Insert a micro-batch's raw prefill cache into batch rows ``rows``
        of layer ``li``'s decode buffer (``kvcache.insert_prefill_rows``)."""
        from repro.serving.kvcache import insert_prefill_rows

        self.cache[li] = insert_prefill_rows(
            self.cfg, kind, self.cache[li], entry, rows
        )

    # -- phases ---------------------------------------------------------
    def _prefill_sctx(self, mb_tokens: int) -> ShardCtx:
        """MoE path for prefill: the grouped dispatch shared with decode,
        with per-expert capacity auto-raised to the micro-batch token count
        — an upper bound on any expert's routed load, so zero drops (and
        thus exactness) by construction, at most E/k x the balanced
        per-expert load at B*S for the planner's b_a."""
        if self.grouped_prefill and self.cfg.has_moe:
            return ShardCtx(moe_dispatch="grouped",
                            moe_capacity=max(1, mb_tokens))
        return ShardCtx()

    def prefill(self, tokens: jax.Array, frontend_emb=None, lengths=None) -> jax.Array:
        """Prefill (attention micro-batched by b_a sequences), filling the
        engine cache.  Returns last logits.

        ``lengths`` (B,) makes a ragged right-padded batch exact: pads are
        masked out of attention/SSM state and each sequence's logits come
        from its true last token.
        """
        B, S = tokens.shape
        self.init_cache(B)
        return self.prefill_slots(
            tokens, np.arange(B), lengths=lengths, frontend_emb=frontend_emb
        )

    def prefill_slots(
        self, tokens: jax.Array, rows, lengths=None, frontend_emb=None
    ) -> jax.Array:
        """Prefill ``tokens`` (n, S) into existing batch rows ``rows`` (n,).

        Layer-major module batching: the outer loop walks layers — each
        layer's weights are pulled through the store ONCE (streamed modules
        prefetched a layer ahead) and reused by every ``b_a``-sequence
        micro-batch of the inner loop.  Also the continuous scheduler's
        admission path: newcomers are prefilled into the slots freed by
        finished sequences, overwriting those rows' KV-cache and SSM state
        while every other slot's state is untouched.  Returns the
        newcomers' last-token logits (n, V).
        """
        cfg, plan = self.cfg, self.plan
        assert self.cache is not None, "init_cache/prefill before prefill_slots"
        n, S = tokens.shape
        assert S <= self.max_seq
        if cfg.sliding_window:
            assert S <= cfg.sliding_window, "engine prefill requires prompt <= window"
        rows = np.asarray(rows)
        lengths = None if lengths is None else jnp.asarray(lengths, jnp.int32)
        b_a = max(1, min(plan.b_a, n))
        spans = [(lo, min(n, lo + b_a)) for lo in range(0, n, b_a)]
        positions = jnp.arange(S)[None, :]
        xs = []
        for lo, hi in spans:
            x = _embed_module(cfg, self.store.base["embed"], tokens[lo:hi])
            if frontend_emb is not None:
                fe = frontend_emb[lo:hi]
                F = fe.shape[1]
                x = jnp.concatenate([fe.astype(x.dtype), x[:, F:]], axis=1)
            xs.append(x)
        for li, (kind, ffn) in enumerate(self.schema):
            p = self.store.acquire(li)
            self.store.prefetch(li + 1)     # hide l+1's copy behind this layer
            outs = []
            for (lo, hi), x in zip(spans, xs):
                sctx = self._prefill_sctx((hi - lo) * S)
                ln = None if lengths is None else lengths[lo:hi]
                y, entry, _ = _prefill_layer_module(
                    cfg, kind, ffn, sctx, p, x, positions, ln
                )
                self._write_cache_rows(li, kind, entry, rows[lo:hi])
                outs.append(y)
            xs = outs
        self.stats.attn_microbatches += len(spans)
        x_full = jnp.concatenate(xs, axis=0)
        if lengths is None:
            h_last = x_full[:, -1]
        else:
            h_last = x_full[jnp.arange(n), lengths - 1]
        return _head_module(cfg, cfg.tie_embeddings, self.store.base, h_last)

    def decode_step(self, tokens: jax.Array, pos) -> jax.Array:
        """One module-batched decode step for all B sequences.

        ``pos`` is the write/attend position: a scalar for uniform batches,
        or a per-sequence (B,) vector for ragged batches and the continuous
        scheduler (each slot decodes at its own sequence position).

        Streamed layers pipeline with compute: layer *l+1*'s weight
        prefetch is issued after layer *l*'s mixer and before its FFN /
        grouped-GEMM launch, so the htod copy rides the async dispatch
        queue behind the step's heaviest compute.
        """
        cfg = self.cfg
        pos = jnp.asarray(pos, jnp.int32)
        x = _embed_module(cfg, self.store.base["embed"], tokens)
        for li, (kind, ffn) in enumerate(self.schema):
            p = self.store.acquire(li)
            if kind == "attn":
                x = x + self._attention_stage(li, p, x, pos)
            else:
                y, state = _ssm_decode_module(cfg, p, x, self.cache[li])
                self.cache[li] = state
                x = x + y
            self.store.prefetch(li + 1)     # before the FFN/grouped launch
            if ffn == "moe":
                x = x + self._expert_stage(p, x)
            elif cfg.d_ff > 0 and "ffn" in p:
                x = x + _ffn_module(cfg, p, x)
        return _head_module(cfg, cfg.tie_embeddings, self.store.base, x)

    # -- module stages ---------------------------------------------------
    def _attention_stage(self, li, p, x, pos) -> jax.Array:
        """Micro-batched attention with the ω host/device split.

        The first ``round(ω·B)`` sequences take the host path.  A micro-batch
        straddling that boundary is split at it, so the realized host
        fraction is exactly ``round(ω·B)/B`` instead of silently rounding a
        whole micro-batch onto the device path.
        """
        cfg, plan = self.cfg, self.plan
        B = x.shape[0]
        n_host = int(round(plan.omega * B))
        outs = []
        b_a = max(1, min(plan.b_a, B))
        k, v = self.cache[li]["k"], self.cache[li]["v"]
        lo = 0
        while lo < B:
            hi = min(B, lo + b_a)
            if lo < n_host < hi:
                hi = n_host                    # split the straddling batch
            fn = (
                _attn_decode_host_module if hi <= n_host
                else _attn_decode_module
            )
            mb_pos = pos if pos.ndim == 0 else pos[lo:hi]
            y, ck, cv = fn(cfg, p, x[lo:hi], k[lo:hi], v[lo:hi], mb_pos)
            k = k.at[lo:hi].set(ck)
            v = v.at[lo:hi].set(cv)
            outs.append(y)
            self.stats.attn_microbatches += 1
            if hi <= n_host:
                self.stats.host_attn_tokens += hi - lo
            else:
                self.stats.device_attn_tokens += hi - lo
            lo = hi
        self.cache[li]["k"], self.cache[li]["v"] = k, v
        return jnp.concatenate(outs, axis=0)

    def _expert_stage(self, p, x) -> jax.Array:
        if self.expert_path == "grouped":
            return self._expert_stage_grouped(p, x)
        return self._expert_stage_loop(p, x)

    def _expert_stage_grouped(self, p, x) -> jax.Array:
        """One grouped-dispatch launch for the whole MoE stage: routing,
        gather, expert FFNs and combine all stay on device (§4.2 realized
        as a single module launch instead of a host-scheduled expert loop)."""
        y, kept, dropped = _grouped_expert_module(
            self.cfg, p, x, self._expert_capacity(x.shape[0])
        )
        self.stats.expert_launches += 1
        self._kept_dev = self._kept_dev + kept
        self._dropped_dev = self._dropped_dev + dropped
        return y

    def _expert_stage_loop(self, p, x) -> jax.Array:
        """Sequential per-expert execution (the seed path, kept as the test
        oracle).  Chunks each expert's gathered tokens by b_e; syncs routing
        to the host every step — the launch pathology the grouped path
        removes."""
        cfg, plan = self.cfg, self.plan
        moe = p["moe"]
        h = _norm2_module(cfg, p, x)
        gates, idx, _ = _router_module(cfg, moe["router"], h)
        idx_np = np.asarray(idx)                     # host-side scheduling
        gates_np = np.asarray(gates)
        y = jnp.zeros_like(x)
        b_e = max(1, plan.b_e)
        for e in range(cfg.num_experts):
            rows, which = np.nonzero(idx_np == e)
            if rows.size == 0:
                continue
            w = gates_np[rows, which]
            for lo in range(0, rows.size, b_e):
                r = rows[lo : lo + b_e]
                g = w[lo : lo + b_e]
                ye = _expert_module(
                    moe["experts_w_gate"][e],
                    moe["experts_w_up"][e],
                    moe["experts_w_down"][e],
                    h[r],
                )
                y = y.at[r].add(ye * jnp.asarray(g)[:, None].astype(ye.dtype))
                self.stats.expert_launches += 1
                self.stats.expert_tokens += int(r.size)
        return y

    def decode_step_sampled(self, tokens: jax.Array, pos, sampler,
                            slots=None) -> jax.Array:
        """One decode tick plus on-device per-slot sampling: runs
        ``decode_step`` and turns the logits into next tokens through a
        ``serving.sampling.BatchSampler`` (mixed greedy/temperature/top-k
        slots, seeded per slot — see that module's determinism contract).
        Returns the (B,) next-token array instead of logits."""
        return sampler.sample(self.decode_step(tokens, pos), slots)

    # -- generation -------------------------------------------------------
    def generate(
        self, tokens: jax.Array, decode_len: int, frontend_emb=None,
        lengths=None, sampling=None,
    ) -> jax.Array:
        """Generation — greedy by default (the paper's decoding strategy,
        §B); pass ``sampling`` (a ``serving.sampling.SamplingParams``) for
        seeded temperature / top-k decoding, applied uniformly with each
        batch row's index folded into its key (rows draw independent
        streams from one seed).

        ``lengths`` (B,) generates from a ragged right-padded batch: each
        sequence decodes at its own positions, token-for-token identical to
        generating it alone unpadded.
        """
        from repro.serving.sampling import BatchSampler

        B, S = tokens.shape
        sampler = BatchSampler.uniform(B, sampling)
        logits = self.prefill(tokens, frontend_emb, lengths=lengths)
        out = [sampler.sample(logits)]
        base = S if lengths is None else jnp.asarray(lengths, jnp.int32)
        for t in range(decode_len - 1):
            out.append(self.decode_step_sampled(out[-1], base + t, sampler))
        result = jnp.stack(out, axis=1)              # (B, decode_len)
        self.sync_stats()                            # fold device counters in
        return result
