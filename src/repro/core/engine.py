"""The MoE-Gen engine: executable module-based batching (paper §4.2).

This is the real thing, not the cost model: given a model's parameters and a
``Plan``, the engine runs generative inference by launching **per-module**
batched computations —

* the attention module consumes micro-batches of ``b_a`` sequences; outputs
  accumulate in host memory until all ``B`` sequences are ready;
* a fraction ``ω`` of each attention batch is computed on the *host* path
  (``core.host_attention``), where the offloaded KV-cache lives;
* the sparse-MoE stage runs as ONE **grouped dispatch**: routed tokens are
  gathered on device into an ``(E, C, D)`` capacity buffer (``C`` = the
  plan's per-expert token budget ``b_e``), pushed through a single grouped
  FFN launch (Pallas on TPU, XLA einsum elsewhere — ``kernels.ops``), and
  scatter-added back weighted by their gates.  Routing indices never leave
  the device, so a decode step issues no host syncs; routed copies beyond
  capacity are dropped and accounted in ``EngineStats``;
* dense modules (SSM blocks, shared FFNs, lm_head) run at full batch.

The seed's sequential per-expert loop is retained as ``expert_path='loop'``
— it is the numerical oracle the grouped path is tested against
(tests/test_grouped_dispatch.py) and the baseline for the loop-vs-grouped
benchmark (benchmarks/engine_walltime.py).

Outputs are bit-compatible with the reference ``models.decode_step`` up to
bf16 accumulation order (asserted in tests/test_engine.py).  Every module is
a separately jitted function — the JAX analogue of the paper's per-module
CUDA launches.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dag_builder import Plan
from repro.core.host_attention import host_decode_attention
from repro.models import attention as attn_mod
from repro.models import model as model_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import ffn_apply
from repro.models.layers import rms_norm


def unstack_layers(cfg: ModelConfig, params: Dict) -> List[Tuple[str, str, Dict]]:
    """Flatten group-stacked layer params into a per-layer list."""
    pattern = model_mod.layer_pattern(cfg)
    G = model_mod.num_groups(cfg)
    layers = []
    for g in range(G):
        for j, (kind, ffn) in enumerate(pattern):
            slot = jax.tree.map(lambda a: a[g], params["layers"][j])
            layers.append((kind, ffn, slot))
    return layers


# ---------------------------------------------------------------------------
# Jitted module launches
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("cfg",))
def _attn_decode_module(cfg, p, x_mb, k, v, pos):
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    y, cache = attn_mod.attn_decode(cfg, p["attn"], h, {"k": k, "v": v}, pos)
    return y[:, 0], cache["k"], cache["v"]


@functools.partial(jax.jit, static_argnames=("cfg",))
def _attn_decode_host_module(cfg, p, x_mb, k, v, pos):
    """Host-path attention: projections on device, mechanism on host CPU
    with the paper's BF16-consistent arithmetic (§B)."""
    from repro.models.layers import apply_rope

    B = x_mb.shape[0]
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    q, k_new, v_new = attn_mod._project_qkv(cfg, p["attn"], h)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,)
    )                                                       # (B,) ragged-safe
    posb = posv[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    span = k.shape[1]
    slot = jnp.where(cfg.sliding_window > 0, posv % span,
                     jnp.minimum(posv, span - 1))
    rows = jnp.arange(B)
    ck = k.at[rows, slot].set(k_new[:, 0])
    cv = v.at[rows, slot].set(v_new[:, 0])
    out = host_decode_attention(q[:, 0], ck, cv, posv)      # (B, H, D) f32
    o = out.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(x_mb.dtype)
    y = o @ p["attn"]["wo"]
    return y[:, 0], ck, cv


@functools.partial(jax.jit, static_argnames=("cfg",))
def _ssm_decode_module(cfg, p, x, state):
    h = rms_norm(x[:, None, :], p["norm1"], cfg.norm_eps)
    y, state = ssm_mod.ssm_decode(cfg, p["ssm"], h, state)
    return y[:, 0], state


@functools.partial(jax.jit, static_argnames=("cfg",))
def _router_module(cfg, router_w, h):
    return moe_mod.route(cfg, router_w, h)


@jax.jit
def _expert_module(wg, wu, wd, h_chunk):
    """One expert over a chunk of tokens (the 'loop' oracle path's unit)."""
    g = h_chunk @ wg
    u = h_chunk @ wu
    return (jax.nn.silu(g) * u) @ wd


@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _grouped_expert_module(cfg, p, x, capacity):
    """The whole MoE stage as one on-device launch sequence: norm -> route ->
    capacity-bucketed gather -> grouped FFN -> weighted scatter-add.
    Returns (y, kept, dropped); the counters stay on device."""
    moe = p["moe"]
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    gates, idx, _ = moe_mod.route(cfg, moe["router"], h)
    return moe_mod.grouped_dispatch(
        cfg, h, gates, idx,
        moe["experts_w_gate"], moe["experts_w_up"], moe["experts_w_down"],
        capacity,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def _ffn_module(cfg, p, x):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return ffn_apply(p["ffn"], h)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _norm2_module(cfg, p, x):
    return rms_norm(x, p["norm2"], cfg.norm_eps)


@functools.partial(jax.jit, static_argnames=("cfg", "tie"))
def _head_module(cfg, tie, params, x):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if tie else params["lm_head"]
    return h @ w


@functools.partial(jax.jit, static_argnames=("cfg",))
def _embed_module(cfg, embed, tokens):
    return jnp.take(embed, tokens, axis=0)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclass
class EngineStats:
    attn_microbatches: int = 0
    expert_launches: int = 0             # grouped: one per MoE layer per step
    expert_tokens: int = 0               # routed token-copies processed
    expert_tokens_dropped: int = 0       # routed copies over the b_e capacity
    host_attn_tokens: int = 0
    device_attn_tokens: int = 0


class ModuleBatchingEngine:
    """Executes a batching ``Plan`` over a real model.

    ``expert_path`` selects the MoE stage implementation:

    * ``'grouped'`` (default) — one jitted grouped-dispatch launch per MoE
      layer; routing stays on device, ``plan.b_e`` is the per-expert token
      capacity ``C`` of the ``(E, C, D)`` dispatch buffer.
    * ``'loop'`` — the seed's host-scheduled sequential per-expert loop,
      kept as the numerical oracle (syncs routing to host every step).

    ``grouped_prefill=True`` additionally routes prefill's MoE stage through
    the same grouped implementation (``ShardCtx(moe_dispatch='grouped')``),
    so both phases share one expert path.  Caveat: prefill capacity comes
    from ``cfg.capacity_factor`` (not ``plan.b_e``), prefill drops are not
    counted in ``EngineStats``, and a ragged batch's pad tokens route too
    (consuming capacity) — opt-in until tuned (see ROADMAP).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        plan: Plan,
        max_seq: int = 512,
        expert_path: str = "grouped",
        grouped_prefill: bool = False,
    ) -> None:
        assert expert_path in ("grouped", "loop"), expert_path
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.max_seq = max_seq
        self.expert_path = expert_path
        self.grouped_prefill = grouped_prefill
        self.layers = unstack_layers(cfg, params)
        self.cache: Optional[List] = None
        self.stats = EngineStats()
        # device-side counters, folded into `stats` by sync_stats(); keeping
        # them lazy is what lets decode_step run without a single host sync.
        self._kept_dev = jnp.zeros((), jnp.int32)
        self._dropped_dev = jnp.zeros((), jnp.int32)

    def _expert_capacity(self, batch: int) -> int:
        """Per-expert capacity C: the plan's b_e, clamped to the most tokens
        any one expert can receive (top-k indices are distinct per token)."""
        return max(1, min(self.plan.b_e, batch))

    def sync_stats(self) -> EngineStats:
        """Materialize the device-side expert counters (one host sync)."""
        self.stats.expert_tokens += int(self._kept_dev)
        self.stats.expert_tokens_dropped += int(self._dropped_dev)
        self._kept_dev = jnp.zeros((), jnp.int32)
        self._dropped_dev = jnp.zeros((), jnp.int32)
        return self.stats

    # -- cache management ---------------------------------------------
    def init_cache(self, batch: int) -> None:
        self.cache = []
        for kind, _, _ in self.layers:
            from repro.models.blocks import init_layer_cache

            self.cache.append(init_layer_cache(self.cfg, kind, batch, self.max_seq))

    # -- phases ---------------------------------------------------------
    def prefill(self, tokens: jax.Array, frontend_emb=None, lengths=None) -> jax.Array:
        """Prefill via the reference forward (attention micro-batched by
        b_a sequences), filling the engine cache.  Returns last logits.

        ``lengths`` (B,) makes a ragged right-padded batch exact: pads are
        masked out of attention/SSM state and each sequence's logits come
        from its true last token (see ``model.forward``).
        """
        B, S = tokens.shape
        self.init_cache(B)
        return self.prefill_slots(
            tokens, np.arange(B), lengths=lengths, frontend_emb=frontend_emb
        )

    def prefill_slots(
        self, tokens: jax.Array, rows, lengths=None, frontend_emb=None
    ) -> jax.Array:
        """Prefill ``tokens`` (n, S) into existing batch rows ``rows`` (n,).

        The continuous scheduler's admission path: newcomers are prefilled
        into the slots freed by finished sequences, overwriting those rows'
        KV-cache and SSM state (``serving.kvcache.scatter_prefill_rows``)
        while every other slot's state is untouched.  Returns the
        newcomers' last-token logits (n, V).
        """
        cfg, plan = self.cfg, self.plan
        assert self.cache is not None, "init_cache/prefill before prefill_slots"
        n, S = tokens.shape
        assert S <= self.max_seq
        if cfg.sliding_window:
            assert S <= cfg.sliding_window, "engine prefill requires prompt <= window"
        from repro.serving.kvcache import scatter_prefill_rows
        from repro.sharding.specs import ShardCtx

        sctx = (
            ShardCtx(moe_dispatch="grouped")
            if (self.grouped_prefill and self.expert_path == "grouped")
            else ShardCtx()
        )
        rows = np.asarray(rows)
        lengths = None if lengths is None else jnp.asarray(lengths, jnp.int32)
        logits_parts = []
        b_a = max(1, min(plan.b_a, n))
        for lo in range(0, n, b_a):
            hi = min(n, lo + b_a)
            mb = tokens[lo:hi]
            fe = None if frontend_emb is None else frontend_emb[lo:hi]
            ln = None if lengths is None else lengths[lo:hi]
            lg, caches = model_mod.prefill(cfg, self.params, mb, fe, sctx, ln)
            logits_parts.append(lg[:, 0])
            scatter_prefill_rows(cfg, self.cache, caches, rows[lo:hi])
            self.stats.attn_microbatches += 1
        return jnp.concatenate(logits_parts, axis=0)

    def decode_step(self, tokens: jax.Array, pos) -> jax.Array:
        """One module-batched decode step for all B sequences.

        ``pos`` is the write/attend position: a scalar for uniform batches,
        or a per-sequence (B,) vector for ragged batches and the continuous
        scheduler (each slot decodes at its own sequence position).
        """
        cfg, plan = self.cfg, self.plan
        B = tokens.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        x = _embed_module(cfg, self.params["embed"], tokens)
        for li, (kind, ffn, p) in enumerate(self.layers):
            if kind == "attn":
                x = x + self._attention_stage(li, p, x, pos)
            else:
                y, state = _ssm_decode_module(cfg, p, x, self.cache[li])
                self.cache[li] = state
                x = x + y
            if ffn == "moe":
                x = x + self._expert_stage(p, x)
            elif cfg.d_ff > 0 and "ffn" in p:
                x = x + _ffn_module(cfg, p, x)
        return _head_module(cfg, cfg.tie_embeddings, self.params, x)

    # -- module stages ---------------------------------------------------
    def _attention_stage(self, li, p, x, pos) -> jax.Array:
        """Micro-batched attention with the ω host/device split.

        The first ``round(ω·B)`` sequences take the host path.  A micro-batch
        straddling that boundary is split at it, so the realized host
        fraction is exactly ``round(ω·B)/B`` instead of silently rounding a
        whole micro-batch onto the device path.
        """
        cfg, plan = self.cfg, self.plan
        B = x.shape[0]
        n_host = int(round(plan.omega * B))
        outs = []
        b_a = max(1, min(plan.b_a, B))
        k, v = self.cache[li]["k"], self.cache[li]["v"]
        lo = 0
        while lo < B:
            hi = min(B, lo + b_a)
            if lo < n_host < hi:
                hi = n_host                    # split the straddling batch
            fn = (
                _attn_decode_host_module if hi <= n_host
                else _attn_decode_module
            )
            mb_pos = pos if pos.ndim == 0 else pos[lo:hi]
            y, ck, cv = fn(cfg, p, x[lo:hi], k[lo:hi], v[lo:hi], mb_pos)
            k = k.at[lo:hi].set(ck)
            v = v.at[lo:hi].set(cv)
            outs.append(y)
            self.stats.attn_microbatches += 1
            if hi <= n_host:
                self.stats.host_attn_tokens += hi - lo
            else:
                self.stats.device_attn_tokens += hi - lo
            lo = hi
        self.cache[li]["k"], self.cache[li]["v"] = k, v
        return jnp.concatenate(outs, axis=0)

    def _expert_stage(self, p, x) -> jax.Array:
        if self.expert_path == "grouped":
            return self._expert_stage_grouped(p, x)
        return self._expert_stage_loop(p, x)

    def _expert_stage_grouped(self, p, x) -> jax.Array:
        """One grouped-dispatch launch for the whole MoE stage: routing,
        gather, expert FFNs and combine all stay on device (§4.2 realized
        as a single module launch instead of a host-scheduled expert loop)."""
        y, kept, dropped = _grouped_expert_module(
            self.cfg, p, x, self._expert_capacity(x.shape[0])
        )
        self.stats.expert_launches += 1
        self._kept_dev = self._kept_dev + kept
        self._dropped_dev = self._dropped_dev + dropped
        return y

    def _expert_stage_loop(self, p, x) -> jax.Array:
        """Sequential per-expert execution (the seed path, kept as the test
        oracle).  Chunks each expert's gathered tokens by b_e; syncs routing
        to the host every step — the launch pathology the grouped path
        removes."""
        cfg, plan = self.cfg, self.plan
        moe = p["moe"]
        h = _norm2_module(cfg, p, x)
        gates, idx, _ = _router_module(cfg, moe["router"], h)
        idx_np = np.asarray(idx)                     # host-side scheduling
        gates_np = np.asarray(gates)
        y = jnp.zeros_like(x)
        b_e = max(1, plan.b_e)
        for e in range(cfg.num_experts):
            rows, which = np.nonzero(idx_np == e)
            if rows.size == 0:
                continue
            w = gates_np[rows, which]
            for lo in range(0, rows.size, b_e):
                r = rows[lo : lo + b_e]
                g = w[lo : lo + b_e]
                ye = _expert_module(
                    moe["experts_w_gate"][e],
                    moe["experts_w_up"][e],
                    moe["experts_w_down"][e],
                    h[r],
                )
                y = y.at[r].add(ye * jnp.asarray(g)[:, None].astype(ye.dtype))
                self.stats.expert_launches += 1
                self.stats.expert_tokens += int(r.size)
        return y

    # -- generation -------------------------------------------------------
    def generate(
        self, tokens: jax.Array, decode_len: int, frontend_emb=None,
        lengths=None,
    ) -> jax.Array:
        """Greedy generation (the paper's decoding strategy, §B).

        ``lengths`` (B,) generates from a ragged right-padded batch: each
        sequence decodes at its own positions, token-for-token identical to
        generating it alone unpadded.
        """
        B, S = tokens.shape
        logits = self.prefill(tokens, frontend_emb, lengths=lengths)
        out = [jnp.argmax(logits, axis=-1)]
        base = S if lengths is None else jnp.asarray(lengths, jnp.int32)
        for t in range(decode_len - 1):
            logits = self.decode_step(out[-1], base + t)
            out.append(jnp.argmax(logits, axis=-1))
        result = jnp.stack(out, axis=1)              # (B, decode_len)
        self.sync_stats()                            # fold device counters in
        return result
