"""The MoE-Gen engine: executable module-based batching (paper §4.2).

This is the real thing, not the cost model: given a model's parameters and a
``Plan``, the engine runs generative inference by launching **per-module**
batched computations —

* the attention module consumes micro-batches of ``b_a`` sequences; outputs
  accumulate in host memory until all ``B`` sequences are ready;
* a fraction ``ω`` of each attention batch is computed on the *host* path
  (``core.host_attention``), where the offloaded KV-cache lives;
* the sparse-MoE stage runs as ONE **grouped dispatch**: routed tokens are
  gathered on device into an ``(E, C, D)`` capacity buffer (``C`` = the
  plan's per-expert token budget ``b_e``), pushed through a single grouped
  FFN launch (Pallas on TPU, XLA einsum elsewhere — ``kernels.ops``), and
  scatter-added back weighted by their gates.  Routing indices never leave
  the device, so a decode step issues no host syncs; routed copies beyond
  capacity are dropped and accounted in ``EngineStats``;
* dense modules (SSM blocks, shared FFNs, lm_head) run at full batch.

**Fused donated decode (the §4/Fig. 5 few-large-launches thesis applied to
the decode hot path).**  When every weight is device-resident
(``ParamStore.fully_resident``) and the expert path is ``'grouped'``, decode
leaves the per-module dispatch loop entirely: ``decode_chunk`` runs embed →
the whole layer schema → head → per-slot sampling as ONE jitted launch
(``_fused_decode_chunk``), with the KV/SSM cache pytree passed in and out
under buffer DONATION and written in place via ``lax.dynamic_update_slice``
— no functional whole-cache copies survive.  A ``lax.scan`` over ``T``
decode ticks keeps the sampled tokens, per-slot positions and sampler
token-indices entirely in-carry on device, so steady-state decode costs one
Python dispatch per ``T`` tokens instead of O(layers·modules·T).  Path
selection is automatic: streamed residency keeps the per-layer loop (the
htod prefetch needs the layer boundary to hide behind), ``expert_path=
'loop'`` keeps the oracle loop, and the ω host-attention rows are kept
OUTSIDE the fused launch — rows ``[0, round(ω·B))`` decode through the
per-module host-path modules while the remaining rows ride the fused
launch (batch rows are independent, so the split is exact).  Fused and
per-module decode are property-tested token-for-token identical
(tests/test_fused_decode.py, tests/test_properties.py).

**Donation contract.**  The engine OWNS the cache pytree between ticks:
``decode_chunk`` (and the per-micro-batch attention/SSM modules, and
``kvcache.evict_rows``) donate the cache buffers to XLA, which invalidates
the previous arrays — callers must never retain references into
``engine.cache`` across a decode tick (take ``np.asarray`` copies instead).
Weights are never donated (they are reused by every launch).

**Weight residency (the paper's S_Params / S_Expert, Fig. 6).**  Every
module stage pulls its parameters through a ``serving.weights.ParamStore``
handle instead of captured dicts.  By default the store pins everything on
device (``resident_bytes=None``); with ``stream_weights=True`` it realizes
``Plan.s_params`` as a greedy resident set (base embed/head first, then
mixers/norms, then expert stacks — ``workload.plan_residency``, the same
policy the planner's cost model charges misses with) and keeps the rest
host-side, served through a double-buffered in-flight window sized by
``Plan.s_expert``: the engine issues the async htod prefetch of layer
*l+1*'s streamed modules before launching layer *l*'s FFN/grouped GEMM, so
the copy hides behind compute with no host syncs.  Streamed generation is
token-for-token identical to fully-resident generation (property-tested in
tests/test_weights.py); transfer bytes and stall seconds are folded into
``EngineStats`` by ``sync_stats()``.

Prefill shares the layer-major structure: each layer's weights are acquired
ONCE and reused across all ``b_a``-sequence micro-batches (module-based
batching's weight amortization), and the MoE stage runs through the same
grouped dispatch as decode (``grouped_prefill=True``, the default) with the
capacity auto-raised to the micro-batch token count so no routed copy is
ever dropped; ``grouped_prefill=False`` opts prefill back into the exact
dense-combine reference MoE, and ``expert_path='loop'`` opts decode into
the seed's sequential per-expert loop.

Outputs are bit-compatible with the reference ``models.decode_step`` up to
bf16 accumulation order (asserted in tests/test_engine.py).  Every module is
a separately jitted function — the JAX analogue of the paper's per-module
CUDA launches — except the fused chunk, which is the paper's point: one.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import runtime as sanitizer
from repro.analysis.markers import hot_path
from repro.analysis.registry import TraceKeySet, register_jit
from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag_builder import Plan
from repro.core.host_attention import host_decode_attention
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import ffn_apply, layer_forward
from repro.models.layers import rms_norm
from repro.serving.sampling import sample_tokens
from repro.serving.weights import ParamStore, unstack_layers  # noqa: F401
from repro.sharding.specs import ShardCtx


# ---------------------------------------------------------------------------
# Dispatch accounting
# ---------------------------------------------------------------------------
_DISPATCHES = 0


def dispatch_count() -> int:
    """Python-side device-dispatch counter: every engine module launch
    (jitted callable invoked from the interpreter) increments it once.  The
    fused decode chunk is exactly ONE dispatch per ``T`` tokens — asserted
    by the regression test in tests/test_fused_decode.py."""
    return _DISPATCHES


def _counted(fn):
    """Wrap a jitted module so each Python-level launch is counted."""

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        global _DISPATCHES
        _DISPATCHES += 1
        return fn(*args, **kwargs)

    return wrapped


# ---------------------------------------------------------------------------
# Jitted module launches (the per-module path)
# ---------------------------------------------------------------------------
@_counted
@register_jit("engine.attn_decode", donated=("k", "v"))
@functools.partial(jax.jit, static_argnames=("cfg", "lo"),
                   donate_argnames=("k", "v"))
def _attn_decode_module(cfg, lo, p, x_mb, k, v, pos):
    """Device-path decode attention over batch rows ``[lo, lo+n)``.

    ``k``/``v`` are the layer's FULL ``(B, span, ...)`` cache buffers,
    DONATED: the micro-batch's rows are sliced out, updated, and written
    back with ``lax.dynamic_update_slice`` so XLA updates the cache in
    place instead of materializing a fresh copy per micro-batch (the seed's
    ``k.at[lo:hi].set`` whole-cache copy)."""
    n = x_mb.shape[0]
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    ck = lax.dynamic_slice_in_dim(k, lo, n, axis=0)
    cv = lax.dynamic_slice_in_dim(v, lo, n, axis=0)
    y, cache = attn_mod.attn_decode(cfg, p["attn"], h, {"k": ck, "v": cv}, pos)
    k = lax.dynamic_update_slice_in_dim(k, cache["k"], lo, axis=0)
    v = lax.dynamic_update_slice_in_dim(v, cache["v"], lo, axis=0)
    return y[:, 0], k, v


@_counted
@register_jit("engine.attn_decode_host", donated=("k", "v"))
@functools.partial(jax.jit, static_argnames=("cfg", "lo"),
                   donate_argnames=("k", "v"))
def _attn_decode_host_module(cfg, lo, p, x_mb, k, v, pos):
    """Host-path attention: projections on device, mechanism on host CPU
    with the paper's BF16-consistent arithmetic (§B).  Same donated
    row-block cache contract as ``_attn_decode_module``."""
    from repro.models.layers import apply_rope

    B = x_mb.shape[0]
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    q, k_new, v_new = attn_mod._project_qkv(cfg, p["attn"], h)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,)
    )                                                       # (B,) ragged-safe
    posb = posv[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    span = k.shape[1]
    slot = jnp.where(cfg.sliding_window > 0, posv % span,
                     jnp.minimum(posv, span - 1))
    rows = jnp.arange(B)
    ck = lax.dynamic_slice_in_dim(k, lo, B, axis=0)
    cv = lax.dynamic_slice_in_dim(v, lo, B, axis=0)
    ck = ck.at[rows, slot].set(k_new[:, 0])
    cv = cv.at[rows, slot].set(v_new[:, 0])
    out = host_decode_attention(q[:, 0], ck, cv, posv)      # (B, H, D) f32
    o = out.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(x_mb.dtype)
    y = o @ p["attn"]["wo"]
    k = lax.dynamic_update_slice_in_dim(k, ck, lo, axis=0)
    v = lax.dynamic_update_slice_in_dim(v, cv, lo, axis=0)
    return y[:, 0], k, v


@_counted
@register_jit("engine.ssm_decode", donated=("h", "conv"))
@functools.partial(jax.jit, static_argnames=("cfg", "lo"),
                   donate_argnames=("h", "conv"))
def _ssm_decode_module(cfg, lo, p, x, h, conv):
    """SSM decode over batch rows ``[lo, lo+n)`` with the state buffers
    donated and written back as row blocks (same contract as attention)."""
    n = x.shape[0]
    sh = lax.dynamic_slice_in_dim(h, lo, n, axis=0)
    sc = lax.dynamic_slice_in_dim(conv, lo, n, axis=0)
    z = rms_norm(x[:, None, :], p["norm1"], cfg.norm_eps)
    y, state = ssm_mod.ssm_decode(cfg, p["ssm"], z, {"h": sh, "conv": sc})
    h = lax.dynamic_update_slice_in_dim(h, state["h"], lo, axis=0)
    conv = lax.dynamic_update_slice_in_dim(conv, state["conv"], lo, axis=0)
    return y[:, 0], h, conv


@_counted
@register_jit("engine.router")
@functools.partial(jax.jit, static_argnames=("cfg",))
def _router_module(cfg, router_w, h):
    return moe_mod.route(cfg, router_w, h)


@_counted
@register_jit("engine.expert")
@jax.jit
def _expert_module(wg, wu, wd, h_chunk):
    """One expert over a chunk of tokens (the 'loop' oracle path's unit)."""
    g = h_chunk @ wg
    u = h_chunk @ wu
    return (jax.nn.silu(g) * u) @ wd


def _grouped_expert_math(cfg, p, x, capacity):
    """The whole MoE stage, traceable: norm -> route -> capacity-bucketed
    gather -> grouped FFN -> weighted scatter-add.  Returns (y, kept,
    dropped, load); the counters — including the (E,) per-expert routed
    histogram feeding the planner's measured-skew b_e search — stay on
    device.  Launched standalone by the per-module path
    (``_grouped_expert_module``) and inlined by the fused decode chunk —
    ONE implementation, so both paths are bit-identical."""
    moe = p["moe"]
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    gates, idx, _ = moe_mod.route(cfg, moe["router"], h)
    return moe_mod.grouped_dispatch(
        cfg, h, gates, idx,
        moe["experts_w_gate"], moe["experts_w_up"], moe["experts_w_down"],
        capacity,
    )


_grouped_expert_module = _counted(
    register_jit("engine.grouped_expert")(
        functools.partial(jax.jit, static_argnames=("cfg", "capacity"))(
            _grouped_expert_math
        )
    )
)


@_counted
@register_jit("engine.route_predict")
@functools.partial(jax.jit, static_argnames=("cfg", "khat"))
def _route_predict_module(cfg, khat, norm2_w, router_w, next_router_w, x):
    """Routing + next-layer expert prediction for the predictive-streamed
    MoE stage: norm2 -> route THIS layer, then score the NEXT streamed MoE
    layer's router on the current hidden state (``moe.predict_experts``).

    Returns ``(h, gates, idx, packed)`` where ``packed`` is one int32
    vector — the (E,) routed-copy counts of this layer (which experts'
    weights the grouped FFN actually needs, and the load histogram the
    capacity re-planner consumes) concatenated with the (k-hat,) predicted
    ids for the next layer — so the engine reads back EVERYTHING it needs
    under ONE planned transfer per layer."""
    h = rms_norm(x, norm2_w, cfg.norm_eps)
    gates, idx, _ = moe_mod.route(cfg, router_w, h)
    used = jnp.zeros((cfg.num_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    pred = moe_mod.predict_experts(cfg, next_router_w, x, khat)
    return h, gates, idx, jnp.concatenate([used, pred])


@_counted
@register_jit("engine.grouped_expert_ffn")
@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _grouped_ffn_module(cfg, capacity, h, gates, idx, wg, wu, wd):
    """Grouped FFN over PRE-ROUTED tokens with externally assembled expert
    stacks — the second half of the predictive-streamed MoE stage.  The
    stacks carry true weights for every expert with a routed copy and the
    zeros filler elsewhere (``ParamStore.acquire_experts``), which is
    bit-identical to the full stack: an unrouted expert's capacity rows are
    all-zero and its outputs are never gathered back."""
    return moe_mod.grouped_dispatch(cfg, h, gates, idx, wg, wu, wd, capacity)


@_counted
@register_jit("engine.ffn")
@functools.partial(jax.jit, static_argnames=("cfg",))
def _ffn_module(cfg, p, x):
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return ffn_apply(p["ffn"], h)


@_counted
@register_jit("engine.norm2")
@functools.partial(jax.jit, static_argnames=("cfg",))
def _norm2_module(cfg, p, x):
    return rms_norm(x, p["norm2"], cfg.norm_eps)


def _head_math(cfg, tie, params, x):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if tie else params["lm_head"]
    return h @ w


_head_module = _counted(
    register_jit("engine.head")(
        functools.partial(jax.jit, static_argnames=("cfg", "tie"))(_head_math)
    )
)


@_counted
@register_jit("engine.embed")
@functools.partial(jax.jit, static_argnames=("cfg",))
def _embed_module(cfg, embed, tokens):
    return jnp.take(embed, tokens, axis=0)


@_counted
@register_jit("engine.prefill_layer")
@functools.partial(jax.jit, static_argnames=("cfg", "kind", "ffn", "sctx"))
def _prefill_layer_module(cfg, kind, ffn, sctx, p, x, positions, lengths):
    """One full layer (mixer + FFN stage) over a prefill micro-batch.

    Prefill's per-layer launch unit: the engine iterates layers in the
    outer loop (weights acquired once per layer, reused by every
    micro-batch) and micro-batches in the inner loop.  ``sctx`` selects the
    MoE path — grouped prefill passes ``moe_capacity`` = the micro-batch
    token count, so no routed copy is dropped."""
    return layer_forward(cfg, kind, ffn, p, x, sctx, positions, lengths)


@_counted
@register_jit("engine.prefill_mixer_route")
@functools.partial(jax.jit, static_argnames=("cfg", "kind"))
def _prefill_mixer_route_module(cfg, kind, p, x, positions, lengths):
    """Mixer half of a grouped-prefill MoE layer, plus routing: norm1 ->
    attention/SSM -> residual -> norm2 -> route.  Splitting the layer here
    lets the engine read back the micro-batch's measured max per-expert
    load (ONE planned scalar per layer per micro-batch) and size the
    grouped FFN's capacity to the next power-of-two bucket >= it, instead
    of pinning capacity to the full micro-batch token count.  Zero-drop —
    and therefore bit-identity with the single-launch layer — holds for
    ANY capacity >= the max load: every routed copy keeps its slot, and
    buffer rows beyond the load are zero-padded lanes whose outputs are
    never gathered back."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        y, entry = attn_mod.attn_forward(cfg, p["attn"], h, ShardCtx(),
                                         positions, lengths)
    else:
        y, entry = ssm_mod.ssm_forward(cfg, p["ssm"], h, ShardCtx(), lengths)
    x = x + y
    hh = rms_norm(x, p["norm2"], cfg.norm_eps)
    xt = hh.reshape(-1, x.shape[-1])
    gates, idx, _ = moe_mod.route(cfg, p["moe"]["router"], xt)
    load = jnp.zeros((cfg.num_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    return x, entry, xt, gates, idx, jnp.max(load), aux


@_counted
@register_jit("engine.prefill_moe_ffn")
@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _prefill_moe_ffn_module(cfg, capacity, moe_p, x, xt, gates, idx):
    """Grouped-FFN half of the split prefill MoE layer, at the measured
    pow2-bucketed ``capacity``.  Same dispatch math as ``moe_apply_grouped``
    (route happened in the mixer half), so the residual-added output is
    bit-identical to the unsplit layer whenever no copy drops — guaranteed
    by capacity >= the measured max load."""
    y, _, dropped, _ = moe_mod.grouped_dispatch(
        cfg, xt, gates, idx,
        moe_p["experts_w_gate"], moe_p["experts_w_up"],
        moe_p["experts_w_down"], capacity,
    )
    B, S, D = x.shape
    return x + y.reshape(B, S, D).astype(x.dtype), dropped


# ---------------------------------------------------------------------------
# Paged decode modules (Mode B: KV host tier — serving.cache.KVPageTable)
# ---------------------------------------------------------------------------
@_counted
@register_jit("engine.paged_attn_decode", donated=("pk", "pv"))
@functools.partial(jax.jit, static_argnames=("cfg", "span"),
                   donate_argnames=("pk", "pv"))
def _paged_attn_decode_module(cfg, span, p, x_mb, pk, pv, ek, ev, frames,
                              pos, wpage, wframe):
    """Device-path decode attention over paged KV.

    ``pk``/``pv`` are the layer's DONATED device page pools
    ``(P+1, pt, K, hd)`` (frame ``P`` is the null write sink); ``ek``/``ev``
    the layer's streamed host frames ``(H, pt, K, hd)`` (the page-tier
    analogue of a streamed weight module — fetched through the same
    ``StreamWindow``).  ``frames`` (n, PP) indexes the concat of both, so
    the gather reassembles each row's ``span`` exactly as the contiguous
    buffer holds it; the attention math is then bit-for-bit
    ``attn_decode`` on identical values.  The written page is extracted
    per row and scattered back at ``wframe`` (host-destined rows scatter
    into the null sink; the engine mirrors their write host-side from the
    returned ``k_new``/``v_new``)."""
    n = x_mb.shape[0]
    pt = pk.shape[1]
    PP = frames.shape[1]
    allk = jnp.concatenate([pk, ek], axis=0)
    allv = jnp.concatenate([pv, ev], axis=0)
    tail = pk.shape[2:]
    gk = allk[frames].reshape((n, PP * pt) + tail)[:, :span]
    gv = allv[frames].reshape((n, PP * pt) + tail)[:, :span]
    # the barrier pins the gather as a standalone producer, so the attn
    # subgraph compiles exactly like the contiguous module's (bit-identity)
    gk, gv = lax.optimization_barrier((gk, gv))
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    y, upd = attn_mod.attn_decode(cfg, p["attn"], h, {"k": gk, "v": gv}, pos)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (n,)
    )
    slot = jnp.where(cfg.sliding_window > 0, posv % span,
                     jnp.minimum(posv, span - 1))
    rows = jnp.arange(n)
    k_new = upd["k"][rows, slot]
    v_new = upd["v"][rows, slot]
    pad = PP * pt - span
    uk, uv = upd["k"], upd["v"]
    if pad:
        uk = jnp.pad(uk, ((0, 0), (0, pad), (0, 0), (0, 0)))
        uv = jnp.pad(uv, ((0, 0), (0, pad), (0, 0), (0, 0)))
    uk = uk.reshape((n, PP, pt) + tail)
    uv = uv.reshape((n, PP, pt) + tail)
    sel = wpage[:, None, None, None, None]
    wk_page = jnp.take_along_axis(uk, sel, axis=1)[:, 0]
    wv_page = jnp.take_along_axis(uv, sel, axis=1)[:, 0]
    pk = pk.at[wframe].set(wk_page)
    pv = pv.at[wframe].set(wv_page)
    return y[:, 0], pk, pv, k_new, v_new


@_counted
@register_jit("engine.paged_attn_host")
@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_attn_host_module(cfg, p, x_mb, gk, gv, pos):
    """Host-path attention over GATHERED page rows: identical math to
    ``_attn_decode_host_module`` (projections + rope on device, the §B
    BF16-consistent mechanism via ``host_decode_attention``), but the
    cache rows arrive pre-assembled from the host/device page pools
    instead of sliced from a contiguous buffer.  Returns the written
    ``k_new``/``v_new`` so the engine mirrors them into the right frame."""
    from repro.models.layers import apply_rope

    B = x_mb.shape[0]
    h = rms_norm(x_mb[:, None, :], p["norm1"], cfg.norm_eps)
    q, k_new, v_new = attn_mod._project_qkv(cfg, p["attn"], h)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,)
    )
    posb = posv[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)
    span = gk.shape[1]
    slot = jnp.where(cfg.sliding_window > 0, posv % span,
                     jnp.minimum(posv, span - 1))
    rows = jnp.arange(B)
    ck = gk.at[rows, slot].set(k_new[:, 0])
    cv = gv.at[rows, slot].set(v_new[:, 0])
    out = host_decode_attention(q[:, 0], ck, cv, posv)
    o = out.reshape(B, 1, cfg.num_heads * cfg.head_dim).astype(x_mb.dtype)
    y = o @ p["attn"]["wo"]
    return y[:, 0], k_new[:, 0], v_new[:, 0]


@_counted
@register_jit("engine.paged_slot_write", donated=("pk", "pv"))
@functools.partial(jax.jit, donate_argnames=("pk", "pv"))
def _paged_slot_write_module(pk, pv, frames, offs, kvals, vvals):
    """Single-slot pool writes for host-path rows whose written page
    spilled onto a device frame; padded to a fixed width with null-frame
    sentinels (the sink absorbs the padding writes)."""
    return (pk.at[frames, offs].set(kvals),
            pv.at[frames, offs].set(vvals))


@_counted
@register_jit("engine.suffix_layer")
@functools.partial(jax.jit, static_argnames=("cfg", "ffn", "sctx"))
def _suffix_layer_module(cfg, ffn, sctx, p, x, pk, pv, pos0):
    """One layer of SUFFIX prefill against a cached prefix (prefix-cache
    hit admission): the suffix queries attend the stored prefix KV
    concatenated with their own, offset to absolute positions ``pos0..``.
    KV at position p depends only on tokens <= p, so the produced suffix
    rows (and logits) are exactly what a full-prompt prefill would
    compute — the shared span costs ZERO prefill launches."""
    from repro.models.layers import apply_rope

    B, S, _ = x.shape
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    q, k, v = attn_mod._project_qkv(cfg, p["attn"], h)
    positions = pos0 + jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    ck = jnp.concatenate([pk, k], axis=1)
    cv = jnp.concatenate([pv, v], axis=1)
    out = attn_mod.naive_attention(q, ck, cv, causal=True, q_offset=pos0)
    o = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    x = x + o @ p["attn"]["wo"]
    if ffn == "moe":
        hh = rms_norm(x, p["norm2"], cfg.norm_eps)
        y, _ = moe_mod.moe_apply(cfg, p["moe"], hh, sctx)
        x = x + y
    elif cfg.d_ff > 0 and "ffn" in p:
        hh = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_apply(p["ffn"], hh)
    return x, k, v


# ---------------------------------------------------------------------------
# The fused decode macro-step (ONE launch per T-token chunk)
# ---------------------------------------------------------------------------
@_counted
@register_jit("engine.fused_decode_chunk", donated=("cache",))
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "schema", "tie", "capacity", "lo", "pos_cap",
                     "use_topk", "greedy_only", "T"),
    donate_argnames=("cache",),
)
def _fused_decode_chunk(cfg, schema, tie, capacity, lo, pos_cap, use_topk,
                        greedy_only, T, base, layer_params, tokens, pos,
                        live, cache, keys, steps, temps, topks):
    """The fused donated decode macro-step: embed → every layer of the
    schema (unrolled — the schema mixes attn/SSM and moe/dense stages) →
    head → per-slot sampling, scanned over ``T`` decode ticks entirely on
    device.  ONE launch per chunk.

    * ``cache`` is the engine's FULL-batch layer cache pytree, DONATED —
      each tick's KV/SSM writes land via ``lax.dynamic_update_slice`` /
      per-row scatter on the aliased buffers, so no whole-cache copy is
      ever materialized, and the caller's previous cache arrays are
      invalidated (the engine owns the pytree between ticks).
    * ``tokens``/``pos`` are the ``n`` fused rows' current tokens and
      positions (rows ``[lo, lo+n)`` of the batch — the ω host-path rows
      ``[0, lo)`` stay OUTSIDE this launch); both advance in-carry, with
      positions clamped at ``pos_cap`` exactly like the per-module
      scheduler tick.
    * ``live`` (n,) bool marks rows owned by an unfinished request: a dead
      (recycled/free) row's carry is HELD — it re-feeds its stale token at
      its stale position every tick, exactly like per-tick stepping, where
      the scheduler never updates a free slot's ``_cur``/``_pos``.  This is
      what keeps chunked decode tick-identical to per-tick decode even
      when expert-capacity drops couple rows through the grouped dispatch.
    * ``keys/steps/temps/topks`` are the rows' ``BatchSampler`` state;
      sampling inlines ``serving.sampling.sample_tokens`` (the SAME
      function the per-module sampler launches) with the token indices
      advancing in-carry, so seeded streams are bit-identical to
      per-module decode.

    Returns ``(toks (n, T), cache, kept, dropped, load)`` — ``dropped`` a
    per-MoE-layer (n_moe,) vector and ``load`` the (n_moe, E) per-expert
    routed-copy histogram, both accumulated in-carry on device.
    """
    n = tokens.shape[0]
    n_moe = sum(1 for _, f in schema if f == "moe")
    E = max(1, cfg.num_experts)
    # optimization barriers mark the per-module boundaries inside the one
    # launch: XLA may not fuse across them, so every module subgraph
    # compiles exactly like its standalone per-module counterpart — which
    # is what makes the fused chunk BIT-identical to per-module decode
    # (cross-module fusion reassociates bf16 reductions otherwise).  The
    # barriers do not split the dispatch: the chunk is still one launch.
    bar = lax.optimization_barrier

    def tick(carry, _):
        toks, pos, cache, steps, kept, dropped, load = carry
        cache = list(cache)
        x = bar(jnp.take(base["embed"], toks, axis=0))
        posv = jnp.minimum(pos, pos_cap)
        moe_j = 0
        for li, (kind, ffn) in enumerate(schema):
            p = layer_params[li]
            if kind == "attn":
                k, v = cache[li]["k"], cache[li]["v"]
                h = rms_norm(x[:, None, :], p["norm1"], cfg.norm_eps)
                ck = lax.dynamic_slice_in_dim(k, lo, n, axis=0)
                cv = lax.dynamic_slice_in_dim(v, lo, n, axis=0)
                y, upd = attn_mod.attn_decode(
                    cfg, p["attn"], h, {"k": ck, "v": cv}, posv
                )
                nk = lax.dynamic_update_slice_in_dim(k, upd["k"], lo, 0)
                nv = lax.dynamic_update_slice_in_dim(v, upd["v"], lo, 0)
                y, nk, nv = bar((y[:, 0], nk, nv))
                cache[li] = {"k": nk, "v": nv}
                x = bar(x + y)
            else:
                hs, cs = cache[li]["h"], cache[li]["conv"]
                sh = lax.dynamic_slice_in_dim(hs, lo, n, axis=0)
                sc = lax.dynamic_slice_in_dim(cs, lo, n, axis=0)
                z = rms_norm(x[:, None, :], p["norm1"], cfg.norm_eps)
                y, st = ssm_mod.ssm_decode(
                    cfg, p["ssm"], z, {"h": sh, "conv": sc}
                )
                nh = lax.dynamic_update_slice_in_dim(hs, st["h"], lo, 0)
                nc = lax.dynamic_update_slice_in_dim(cs, st["conv"], lo, 0)
                y, nh, nc = bar((y[:, 0], nh, nc))
                cache[li] = {"h": nh, "conv": nc}
                x = bar(x + y)
            if ffn == "moe":
                y, kp, dr, ld = _grouped_expert_math(cfg, p, x, capacity)
                y, kp, dr, ld = bar((y, kp, dr, ld))
                kept = kept + kp
                dropped = dropped.at[moe_j].add(dr)
                load = load.at[moe_j].add(ld)
                moe_j += 1
                x = bar(x + y)
            elif cfg.d_ff > 0 and "ffn" in p:
                y = bar(ffn_apply(p["ffn"],
                                  rms_norm(x, p["norm2"], cfg.norm_eps)))
                x = bar(x + y)
        logits = bar(_head_math(cfg, tie, base, x))
        if greedy_only:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = sample_tokens(logits, keys, steps, temps, topks, use_topk)
        carry_tok = jnp.where(live, nxt, toks)     # dead rows hold stale tok
        carry_pos = pos + live.astype(pos.dtype)   # ...at their stale pos
        return (carry_tok, carry_pos, tuple(cache), steps + 1, kept,
                dropped, load), nxt

    zero = jnp.zeros((), jnp.int32)
    carry0 = (tokens, pos, tuple(cache), steps, zero,
              jnp.zeros((n_moe,), jnp.int32), jnp.zeros((n_moe, E), jnp.int32))
    (_, _, cache, _, kept, dropped, load), ys = lax.scan(
        tick, carry0, None, length=T
    )
    return jnp.swapaxes(ys, 0, 1), cache, kept, dropped, load


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclass
class EngineStats:
    attn_microbatches: int = 0
    expert_launches: int = 0             # grouped: one per MoE layer per step
    expert_tokens: int = 0               # routed token-copies processed
    expert_tokens_dropped: int = 0       # routed copies over the b_e capacity
    host_attn_tokens: int = 0
    device_attn_tokens: int = 0
    weight_htod_bytes: int = 0           # streamed weight bytes copied htod
    prefetch_wait_s: float = 0.0         # stall waiting on weight transfers
    fused_dispatches: int = 0            # fused decode launches issued
    fused_ticks: int = 0                 # decode ticks served by fused launches
    decode_retraces: int = 0             # distinct fused (B, path, chunk) keys
    kv_htod_bytes: int = 0               # streamed KV-page bytes copied htod
    kv_dtoh_bytes: int = 0               # KV bytes spilled device->host
    kv_stream_wait_s: float = 0.0        # stall waiting on page transfers
    expert_tokens_dropped_by_layer: Optional[np.ndarray] = None
    #                                      (n_moe,) int64 per-MoE-layer drops;
    #                                      sums to expert_tokens_dropped
    expert_load: Optional[np.ndarray] = None
    #                                      (n_moe, E) int64 routed-copy
    #                                      histogram (pre-capacity) — the
    #                                      measured-skew input to the
    #                                      planner's capacity_for_load
    expert_pred_hits: int = 0            # routed experts found prefetched
    expert_pred_misses: int = 0          # routed experts demand-fetched
    expert_lru_hits: int = 0             # routed experts served from the LRU
    expert_lru_bytes: int = 0            # device bytes the hot-expert LRU pins
    a2a_bytes: int = 0                   # interconnect bytes the mesh MoE
    #                                      stage exchanged (a2a dispatch +
    #                                      return; 0 off-mesh / psum path)
    collective_dispatches: int = 0       # mesh MoE stage launches (a2a/psum)
    transfer_retries: int = 0            # transient stream-fetch failures
    #                                      recovered by the retry policy
    #                                      (weight + expert + KV-page windows)
    transfer_timeouts: int = 0           # watchdog-expired acquire waits
    #                                      recovered by demand re-fetch


class ModuleBatchingEngine:
    """Executes a batching ``Plan`` over a real model.

    ``expert_path`` selects the MoE stage implementation:

    * ``'grouped'`` (default) — one jitted grouped-dispatch launch per MoE
      layer; routing stays on device, ``plan.b_e`` is the per-expert token
      capacity ``C`` of the ``(E, C, D)`` dispatch buffer.  Prefill shares
      the same grouped implementation (``grouped_prefill=True``, the
      default) with the capacity auto-raised to the micro-batch token count
      (never below, so zero ``expert_tokens_dropped`` at prefill by
      construction); pass ``grouped_prefill=False`` for the exact-reference
      dense-combine prefill.
    * ``'loop'`` — the seed's host-scheduled sequential per-expert loop,
      kept as the numerical oracle (syncs routing to host every step).

    ``grouped_prefill`` is independent of ``expert_path`` (prefill and
    decode paths are selected separately), so a loop-decode engine still
    shares the grouped prefill numerics by default and grouped-vs-loop
    generation stays token-for-token comparable.

    **Fused decode selection.**  ``decode_chunk``/``decode_step_sampled``
    take the fused one-launch path automatically when ``fused_decode=True``
    (default), the expert path is grouped, and the store is fully resident
    (``fused_eligible()``).  Streamed residency falls back to the
    per-module loop (the prefetch needs the layer boundary); the ω
    host-attention rows always decode per-module, outside the fused
    launch.  ``fused_decode=False`` forces the per-module path — the
    oracle the fused path is property-tested against.

    **Weight residency.**  All module stages read parameters through
    ``self.store`` (a ``serving.weights.ParamStore``).  By default every
    weight is device-resident.  ``stream_weights=True`` keeps only the plan's
    ``s_params`` greedy resident set on device and streams the rest from
    host through a double-buffered async prefetch window (``prefetch=False``
    degrades to serialized on-demand fetches); ``resident_bytes`` overrides
    the budget.  A pre-built ``store`` can be passed directly.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        plan: Plan,
        max_seq: int = 512,
        expert_path: str = "grouped",
        grouped_prefill: bool = True,
        store: Optional[ParamStore] = None,
        stream_weights: bool = False,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
        fused_decode: bool = True,
        cache_config=None,
        sctx: Optional[ShardCtx] = None,
        ep_chunks: int = 1,
        ep_serial: bool = False,
    ) -> None:
        assert expert_path in ("grouped", "loop"), expert_path
        self.cfg = cfg
        self.plan = plan
        self.max_seq = max_seq
        self.expert_path = expert_path
        self.grouped_prefill = grouped_prefill
        self.fused_decode = fused_decode
        # mesh engine (ShardCtx threading — the moe_dispatch='a2a'/'psum'
        # paths were unreachable from the engine before): a ShardCtx with a
        # mesh + model axis routes the grouped MoE stage through the
        # collective dispatch in repro.distributed.ep_engine; everything
        # else (attention, prefill, sampling) stays the single-device path
        self.sctx = (sctx if sctx is not None and sctx.mesh is not None
                     and sctx.model_axis is not None else None)
        self.ep_chunks = max(1, int(ep_chunks))
        self.ep_serial = bool(ep_serial)
        self._ep_params: Dict = {}       # per-layer mesh-placed MoE params
        if self.sctx is not None:
            from repro.distributed.ep_engine import validate_ep_shard

            validate_ep_shard(cfg, self.sctx)
            if expert_path != "grouped":
                raise ValueError(
                    "a mesh ShardCtx replaces the grouped MoE stage with "
                    "the collective dispatch; expert_path='loop' is "
                    "single-device only"
                )
            if self.sctx.moe_dispatch == "a2a" and plan.predict_topk > 0:
                raise ValueError(
                    "moe_dispatch='a2a' does not compose with predictive "
                    "per-expert streaming (predict_topk > 0) for now: the "
                    "a2a stage needs every rank's expert shard resident"
                )
            if stream_weights:
                raise ValueError(
                    "stream_weights does not compose with a mesh ShardCtx: "
                    "the collective stage needs resident expert shards"
                )
        # KV paging (serving.cache): None / disabled keeps the legacy
        # contiguous buffers; the table is (re)built per init_cache batch
        self.cache_config = cache_config
        self.pages = None
        if store is None:
            store = ParamStore.build(
                cfg, params, plan, stream_weights=stream_weights,
                resident_bytes=resident_bytes, prefetch=prefetch,
            )
        self.store = store
        if self.sctx is not None and not store.fully_resident:
            raise ValueError(
                "a mesh ShardCtx needs a fully resident ParamStore: the "
                "collective MoE stage shards whole expert stacks across "
                "the model axis and cannot stream them"
            )
        self.schema = store.schema                  # [(kind, ffn)] per layer
        # kept for introspection/back-compat: (kind, ffn, _) triples
        self.layers: List[Tuple[str, str, None]] = [
            (k, f, None) for k, f in self.schema
        ]
        self.cache: Optional[List] = None
        self.stats = EngineStats()
        # device-side counters, folded into `stats` by sync_stats(); keeping
        # them lazy is what lets decode_step run without a single host sync.
        # Drops and routed-load histograms accumulate PER MoE LAYER — the
        # vectors stay on device (vector += vector only, no indexing inside
        # decode regions, which would upload index scalars under the
        # transfer guard).
        self._moe_layers = [li for li, (_, f) in enumerate(self.schema)
                            if f == "moe"]
        self._moe_index = {li: j for j, li in enumerate(self._moe_layers)}
        n_moe = len(self._moe_layers)
        E = max(1, cfg.num_experts)
        self._kept_dev = jnp.zeros((), jnp.int32)
        self._dropped_dev_l = [jnp.zeros((), jnp.int32)
                               for _ in range(n_moe)]
        self._load_dev_l = [jnp.zeros((E,), jnp.int32) for _ in range(n_moe)]
        self._dropped_chunk_dev = jnp.zeros((n_moe,), jnp.int32)
        self._load_chunk_dev = jnp.zeros((n_moe, E), jnp.int32)
        # online capacity re-plan hook (serving.Server): overrides the
        # plan's b_e when measured routing skew drifts; None = plan value
        self._b_e_override: Optional[int] = None
        # predictive-streaming test seam: when set, a callable
        # ``predictor(next_layer, khat) -> iterable expert ids`` replaces
        # the device-computed prediction for PREFETCH decisions only —
        # correctness is predictor-independent (mispredictions demand-fetch)
        self.predictor = None
        self._batch = 0
        # fused-path bookkeeping: per-layer param tuple (aliases the
        # resident arrays) and the set of (B, path, chunk) trace keys seen
        # (a new key = one XLA retrace, surfaced as stats.decode_retraces;
        # the TraceKeySet registers with repro.analysis so the sanitizer
        # report folds it in next to the XLA compile counts)
        self._fused_params: Optional[Tuple[Dict, ...]] = None
        self._fused_keys = TraceKeySet("engine.fused_decode_chunk")

    def _expert_capacity(self, batch: int) -> int:
        """Per-expert capacity C: the plan's b_e (or the online re-plan
        override), clamped to the most tokens any one expert can receive
        (top-k indices are distinct per token)."""
        b_e = (self.plan.b_e if self._b_e_override is None
               else self._b_e_override)
        return max(1, min(b_e, batch))

    def set_expert_capacity(self, b_e: Optional[int]) -> None:
        """Online capacity re-plan entry point (``Server`` calls this when
        measured routing skew drifts): override the plan's ``b_e`` for
        subsequent decode dispatches.  ``None`` restores the plan value.
        Changing capacity changes the dispatch-buffer shape, so the next
        fused chunk retraces ONCE (counted in ``decode_retraces``)."""
        self._b_e_override = None if b_e is None else max(1, int(b_e))

    def sync_stats(self) -> EngineStats:
        """Materialize the device-side expert counters (one host sync) and
        drain the store's transfer + predictive-streaming accounting."""
        self.stats.expert_tokens += int(self._kept_dev)
        self._kept_dev = jnp.zeros((), jnp.int32)
        n_moe = len(self._moe_layers)
        if n_moe:
            E = self._load_chunk_dev.shape[1]
            dropped = np.asarray(self._dropped_chunk_dev, np.int64) + np.array(
                [int(d) for d in self._dropped_dev_l], np.int64
            )
            load = np.asarray(self._load_chunk_dev, np.int64) + np.stack(
                [np.asarray(v, np.int64) for v in self._load_dev_l]
            )
            self.stats.expert_tokens_dropped += int(dropped.sum())
            if self.stats.expert_tokens_dropped_by_layer is None:
                self.stats.expert_tokens_dropped_by_layer = np.zeros(
                    n_moe, np.int64
                )
                self.stats.expert_load = np.zeros((n_moe, E), np.int64)
            self.stats.expert_tokens_dropped_by_layer += dropped
            self.stats.expert_load += load
            self._dropped_dev_l = [jnp.zeros((), jnp.int32)
                                   for _ in range(n_moe)]
            self._load_dev_l = [jnp.zeros((E,), jnp.int32)
                                for _ in range(n_moe)]
            self._dropped_chunk_dev = jnp.zeros((n_moe,), jnp.int32)
            self._load_chunk_dev = jnp.zeros((n_moe, E), jnp.int32)
        htod, wait = self.store.take_counters()
        self.stats.weight_htod_bytes += htod
        self.stats.prefetch_wait_s += wait
        take_ec = getattr(self.store, "take_expert_counters", None)
        if take_ec is not None:
            ec = take_ec()
            self.stats.expert_pred_hits += ec["pred_hits"]
            self.stats.expert_pred_misses += ec["pred_misses"]
            self.stats.expert_lru_hits += ec["lru_hits"]
            self.stats.expert_lru_bytes = ec["lru_bytes_used"]
        if self.pages is not None:
            kv_htod, kv_dtoh, kv_wait = self.pages.take_counters()
            self.stats.kv_htod_bytes += kv_htod
            self.stats.kv_dtoh_bytes += kv_dtoh
            self.stats.kv_stream_wait_s += kv_wait
        for taker in (getattr(self.store, "take_fault_counters", None),
                      getattr(self.pages, "take_fault_counters", None)):
            if taker is not None:
                retries, timeouts = taker()
                self.stats.transfer_retries += retries
                self.stats.transfer_timeouts += timeouts
        return self.stats

    # -- cache management ---------------------------------------------
    def init_cache(self, batch: int) -> None:
        from repro.models.blocks import init_layer_cache

        self.cache = []
        self._batch = batch
        self.pages = None
        cc = self.cache_config
        if (cc is not None and cc.enabled
                and any(k == "attn" for k, _ in self.schema)):
            from repro.serving.cache import KVPageTable

            self.pages = KVPageTable(
                self.cfg, self.schema, batch, self.max_seq, cc
            )
        paged_b = self.pages is not None and not self.pages.fully_resident
        for kind, _ in self.schema:
            if kind == "attn" and paged_b:
                # Mode B: KV content lives in the page pools; the empty
                # dict keeps the cache pytree tree.map/evict-safe
                self.cache.append({})
            else:
                self.cache.append(
                    init_layer_cache(self.cfg, kind, batch, self.max_seq)
                )

    def _write_cache_rows(self, li: int, kind: str, entry: Dict, rows) -> None:
        """Insert a micro-batch's raw prefill cache into batch rows ``rows``
        of layer ``li``'s decode buffer (``kvcache.insert_prefill_rows``) —
        or, under paging, into the rows' page frames (allocated on first
        touch; the ω host-attention rows prefer the host tier so the page
        placement generalizes the math-path split)."""
        from repro.serving.kvcache import aligned_kv, insert_prefill_rows

        if kind == "attn" and self.pages is not None:
            rows_l = [int(r) for r in np.asarray(rows).reshape(-1)]
            n_host = int(round(self.plan.omega * (self._batch or len(rows_l))))
            self.pages.ensure_rows(
                rows_l, prefer_host=[r < n_host for r in rows_l]
            )
            if not self.pages.fully_resident:
                nk, nv = aligned_kv(
                    self.cfg, entry["k"], entry["v"], self.pages.span
                )
                self.pages.insert_rows(li, nk, nv, rows_l)
                return
        self.cache[li] = insert_prefill_rows(
            self.cfg, kind, self.cache[li], entry, rows
        )

    def evict_slots(self, rows) -> None:
        """Recycle batch slots: zero the contiguous rows (one donated
        ``kvcache.evict_rows`` launch) and return any page frames to the
        table's free lists.  THE slot-recycling entry point — callers must
        not evict the cache list directly once paging is on."""
        from repro.serving.kvcache import evict_rows

        assert self.cache is not None
        stale = self._stale_snapshot()
        self.cache = evict_rows(self.cache, rows)
        if self.pages is not None:
            self.pages.free_rows([int(r) for r in np.asarray(rows).reshape(-1)])
        self._poison_stale(stale)

    def reserve_slot_rows(self, rows) -> None:
        """Pre-admission page-frame reservation for batch rows ``rows``
        (no-op without paging; idempotent — ``_write_cache_rows`` reuses
        the placement).  Raises ``faults.PageAllocOOM`` when the table is
        out of frames (or an armed fault plan injects one) BEFORE any
        prefill compute is spent, so the scheduler can degrade gracefully
        (defer / demote / shrink) instead of aborting mid-wave."""
        if self.pages is None:
            return
        rows_l = [int(r) for r in np.asarray(rows).reshape(-1)]
        n_host = int(round(self.plan.omega * (self._batch or len(rows_l))))
        self.pages.ensure_rows(
            rows_l, prefer_host=[r < n_host for r in rows_l]
        )

    # -- preemption checkpoints -------------------------------------------
    def checkpoint_slot(self, slot: int) -> List[Dict[str, np.ndarray]]:
        """Snapshot batch row ``slot``'s FULL per-layer decode state as
        host-side numpy (attention KV rows — contiguous or paged — and SSM
        h/conv state): the KV half of a request preemption checkpoint.
        Host copies are donation-safe to retain across later ticks."""
        from repro.serving.kvcache import snapshot_row

        assert self.cache is not None
        slot = int(slot)
        out: List[Dict[str, np.ndarray]] = []
        with sanitizer.allowed("ckpt-save"):
            for li, (kind, _) in enumerate(self.schema):
                if (kind == "attn" and self.pages is not None
                        and not self.pages.fully_resident):
                    k, v = self.pages.read_row(li, slot, self.pages.span)
                    out.append({"k": k, "v": v})
                else:
                    out.append(snapshot_row(self.cache[li], slot))
        return out

    def restore_slot(self, slot: int, state: List[Dict[str, np.ndarray]]) -> None:
        """Write a ``checkpoint_slot`` snapshot back into batch row
        ``slot`` (resume): page frames are re-reserved (may raise
        ``PageAllocOOM`` — the resume then stays queued) and every layer's
        rows are restored eagerly.  With the sampler key/step and ``pos``
        restored by the scheduler, decode continues bit-identical to the
        unpreempted run — zero prefill relaunches."""
        from repro.serving.kvcache import restore_row

        assert self.cache is not None
        slot = int(slot)
        if self.pages is not None:
            self.reserve_slot_rows([slot])
        with sanitizer.allowed("ckpt-restore"):
            for li, (kind, _) in enumerate(self.schema):
                st = state[li]
                if (kind == "attn" and self.pages is not None
                        and not self.pages.fully_resident):
                    self.pages.insert_rows(
                        li, jnp.asarray(st["k"])[None],
                        jnp.asarray(st["v"])[None], [slot]
                    )
                    continue
                self.cache[li] = restore_row(self.cache[li], slot, st)

    # -- sanitizer hooks -------------------------------------------------
    def _stale_snapshot(self) -> Optional[List]:
        """Pre-launch array leaves of every buffer the decode tick may
        donate (cache pytree + page pools) — captured only in poison mode
        so ``_poison_stale`` can invalidate whatever XLA didn't consume."""
        san = sanitizer.current()
        if san is None or not san.poison or self.cache is None:
            return None
        trees = [self.cache]
        if self.pages is not None:
            trees.extend([self.pages.pool_k, self.pages.pool_v])
        return jax.tree.leaves(trees)

    def _poison_stale(self, stale: Optional[List]) -> None:
        """Debug-mode stale-buffer poisoner (ROADMAP cache-donation
        contract): delete pre-launch buffers that are neither part of the
        rebound cache/pools nor already consumed by donation, so retained
        references into ``engine.cache``/``pool_k``/``pool_v`` across a
        tick fail loudly instead of reading garbage."""
        if stale is None:
            return
        trees = [self.cache]
        if self.pages is not None:
            trees.extend([self.pages.pool_k, self.pages.pool_v])
        sanitizer.poison_stale(stale, trees)

    # -- phases ---------------------------------------------------------
    def _prefill_sctx(self, mb_tokens: int) -> ShardCtx:
        """MoE path for prefill: the grouped dispatch shared with decode,
        with per-expert capacity auto-raised to the micro-batch token count
        — an upper bound on any expert's routed load, so zero drops (and
        thus exactness) by construction, at most E/k x the balanced
        per-expert load at B*S for the planner's b_a."""
        if self.grouped_prefill and self.cfg.has_moe:
            return ShardCtx(moe_dispatch="grouped",
                            moe_capacity=max(1, mb_tokens))
        return ShardCtx()

    def prefill(self, tokens: jax.Array, frontend_emb=None, lengths=None) -> jax.Array:
        """Prefill (attention micro-batched by b_a sequences), filling the
        engine cache.  Returns last logits.

        ``lengths`` (B,) makes a ragged right-padded batch exact: pads are
        masked out of attention/SSM state and each sequence's logits come
        from its true last token.
        """
        B, S = tokens.shape
        self.init_cache(B)
        return self.prefill_slots(
            tokens, np.arange(B), lengths=lengths, frontend_emb=frontend_emb
        )

    def prefill_slots(
        self, tokens: jax.Array, rows, lengths=None, frontend_emb=None
    ) -> jax.Array:
        """Prefill ``tokens`` (n, S) into existing batch rows ``rows`` (n,).

        Layer-major module batching: the outer loop walks layers — each
        layer's weights are pulled through the store ONCE (streamed modules
        prefetched a layer ahead) and reused by every ``b_a``-sequence
        micro-batch of the inner loop.  Also the continuous scheduler's
        admission path: newcomers are prefilled into the slots freed by
        finished sequences, overwriting those rows' KV-cache and SSM state
        while every other slot's state is untouched.  Returns the
        newcomers' last-token logits (n, V).
        """
        cfg, plan = self.cfg, self.plan
        assert self.cache is not None, "init_cache/prefill before prefill_slots"
        n, S = tokens.shape
        assert S <= self.max_seq
        if cfg.sliding_window:
            assert S <= cfg.sliding_window, "engine prefill requires prompt <= window"
        rows = np.asarray(rows)
        lengths = None if lengths is None else jnp.asarray(lengths, jnp.int32)
        b_a = max(1, min(plan.b_a, n))
        spans = [(lo, min(n, lo + b_a)) for lo in range(0, n, b_a)]
        positions = jnp.arange(S)[None, :]
        xs = []
        for lo, hi in spans:
            x = _embed_module(cfg, self.store.base["embed"], tokens[lo:hi])
            if frontend_emb is not None:
                fe = frontend_emb[lo:hi]
                F = fe.shape[1]
                x = jnp.concatenate([fe.astype(x.dtype), x[:, F:]], axis=1)
            xs.append(x)
        for li, (kind, ffn) in enumerate(self.schema):
            p = self.store.acquire(li)
            self.store.prefetch(li + 1)     # hide l+1's copy behind this layer
            # grouped-prefill MoE layers split into mixer+route / grouped-FFN
            # launches so the FFN capacity can be the next pow2 bucket over
            # the micro-batch's MEASURED max expert load instead of the full
            # token count — smaller (E, C, D) buffers, zero drops preserved
            split_moe = ffn == "moe" and self.grouped_prefill
            outs = []
            for (lo, hi), x in zip(spans, xs):
                ln = None if lengths is None else lengths[lo:hi]
                if split_moe:
                    x_mid, entry, xt, gates, idx, max_load, _ = (
                        _prefill_mixer_route_module(
                            cfg, kind, p, x, positions, ln
                        )
                    )
                    with sanitizer.allowed("prefill-capacity-probe"):
                        cap = W.next_pow2(int(np.asarray(max_load)))
                    y, _ = _prefill_moe_ffn_module(
                        cfg, cap, p["moe"], x_mid, xt, gates, idx
                    )
                else:
                    sctx = self._prefill_sctx((hi - lo) * S)
                    y, entry, _ = _prefill_layer_module(
                        cfg, kind, ffn, sctx, p, x, positions, ln
                    )
                self._write_cache_rows(li, kind, entry, rows[lo:hi])
                outs.append(y)
            xs = outs
        self.stats.attn_microbatches += len(spans)
        x_full = jnp.concatenate(xs, axis=0)
        if lengths is None:
            h_last = x_full[:, -1]
        else:
            h_last = x_full[jnp.arange(n), lengths - 1]
        return _head_module(cfg, cfg.tie_embeddings, self.store.base, h_last)

    # -- prefix caching ---------------------------------------------------
    def read_prefix_rows(self, slot: int, pspan: int) -> List:
        """Copy the first ``pspan`` KV slots of batch row ``slot`` out of
        every attention layer as numpy ``(k, v)`` pairs — the capture side
        of the prefix cache (host-side copies, safe to retain across the
        donated decode ticks)."""
        out = []
        for li, (kind, _) in enumerate(self.schema):
            assert kind == "attn", "prefix capture requires attention-only"
            if self.pages is not None and not self.pages.fully_resident:
                out.append(self.pages.read_row(li, slot, pspan))
            else:
                out.append((np.asarray(self.cache[li]["k"][slot, :pspan]),
                            np.asarray(self.cache[li]["v"][slot, :pspan])))
        return out

    def prefill_prefix_hit(self, slot: int, prompt, prefix_kvs,
                           pos0: int) -> jax.Array:
        """Admit a prefix-cache HIT into batch row ``slot``: the stored
        prefix KV rows are copied in (KV at position p depends only on
        tokens <= p, so they equal what full prefill would write) and only
        the suffix ``prompt[pos0:]`` is prefilled, its queries attending
        prefix+suffix at absolute positions.  Launch count is
        ``n_layers + 2`` (embed + one suffix module per layer + head) —
        INDEPENDENT of the prefix length: the shared span costs zero
        prefill launches.  Returns the (1, V) last-token logits."""
        cfg = self.cfg
        assert self.cache is not None
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert 0 < pos0 < len(prompt), (pos0, len(prompt))
        suffix = jnp.asarray(prompt[pos0:])[None, :]
        S_suf = int(suffix.shape[1])
        x = _embed_module(cfg, self.store.base["embed"], suffix)
        sctx = self._prefill_sctx(S_suf)
        pos0j = jnp.asarray(pos0, jnp.int32)
        for li, (kind, ffn) in enumerate(self.schema):
            assert kind == "attn", "prefix cache requires attention-only"
            p = self.store.acquire(li)
            self.store.prefetch(li + 1)
            pk = jnp.asarray(prefix_kvs[li][0])[None]
            pv = jnp.asarray(prefix_kvs[li][1])[None]
            x, ks, vs = _suffix_layer_module(cfg, ffn, sctx, p, x, pk, pv,
                                             pos0j)
            entry = {"k": jnp.concatenate([pk, ks], axis=1),
                     "v": jnp.concatenate([pv, vs], axis=1)}
            self._write_cache_rows(li, "attn", entry, [slot])
        self.stats.attn_microbatches += 1
        return _head_module(cfg, cfg.tie_embeddings, self.store.base,
                            x[:, -1])

    # -- path selection ---------------------------------------------------
    def fused_eligible(self) -> bool:
        """True when decode can take the fused one-launch path: fused
        decode enabled, grouped expert dispatch, and EVERY weight resident
        on device (streamed layers keep the per-layer dispatch loop so the
        htod prefetch has a layer boundary to overlap with).  Same contract
        for KV pages: a fully-device-resident page pool (Mode A) keeps the
        fused path BIT-identical, any host-tier page falls back to the
        per-layer loop like streamed weights.  A mesh engine (``sctx``)
        always decodes per-module: the collective MoE stage needs its own
        launch boundary between the attention and FFN stages."""
        return (self.fused_decode and self.expert_path == "grouped"
                and self.sctx is None
                and self.store.fully_resident
                and (self.pages is None or self.pages.fully_resident))

    def _fused_layer_params(self) -> Tuple[Dict, ...]:
        if self._fused_params is None:
            self._fused_params = self.store.fused_layer_params()
        return self._fused_params

    # -- decode -----------------------------------------------------------
    def decode_step(self, tokens: jax.Array, pos) -> jax.Array:
        """One PER-MODULE decode step for all B sequences; returns logits.

        ``pos`` is the write/attend position: a scalar for uniform batches,
        or a per-sequence (B,) vector for ragged batches and the continuous
        scheduler (each slot decodes at its own sequence position).

        Streamed layers pipeline with compute: layer *l+1*'s weight
        prefetch is issued after layer *l*'s mixer and before its FFN /
        grouped-GEMM launch, so the htod copy rides the async dispatch
        queue behind the step's heaviest compute.  (The fused one-launch
        path lives in ``decode_chunk``; this method is the per-module
        oracle and the streamed/loop execution path.)
        """
        stale = self._stale_snapshot()
        with sanitizer.allowed("decode-inputs"):
            pos = jnp.asarray(pos, jnp.int32)
            tokens = jnp.asarray(tokens)
        with sanitizer.decode_region():
            logits = self._decode_rows(tokens, pos, 0)
        self._poison_stale(stale)
        return logits

    @hot_path
    def _decode_rows(self, tokens, pos, row0: int, pos_host=None) -> jax.Array:
        """Per-module decode over batch rows ``[row0, row0+n)`` — ``tokens``
        and ``pos`` are the rows' own (n,)/scalar arrays.  The full-batch
        ``decode_step`` is ``row0=0``; the fused path calls it with the ω
        host segment so host-path rows decode outside the fused launch.

        ``pos_host`` is the rows' positions as a host (numpy) mirror —
        Mode B paging does host-side position math for its page-table
        bookkeeping, and threading the mirror from the caller keeps that
        at ONE planned readback per tick instead of one per layer."""
        cfg = self.cfg
        if (pos_host is None and self.pages is not None
                and not self.pages.fully_resident):
            with sanitizer.allowed("decode-pos-host-mirror"):
                pos_host = np.asarray(pos, np.int32)  # lint: allow[MG101] planned once-per-tick position readback for the page table
        x = _embed_module(cfg, self.store.base["embed"], tokens)
        for li, (kind, ffn) in enumerate(self.schema):
            # predictive-streamed MoE layers skip the full expert-stack
            # assembly in acquire(): the stage fetches only the experts the
            # router actually used (plus LRU hits) and prefetches the
            # predicted set for the next streamed MoE layer
            predictive = (ffn == "moe" and self.expert_path == "grouped"
                          and self.store.streams_experts(li))
            p = self.store.acquire(li, experts=not predictive)
            if kind == "attn":
                x = x + self._attention_stage(li, p, x, pos, row0, pos_host)
            else:
                y, h, conv = _ssm_decode_module(
                    cfg, row0, p, x, self.cache[li]["h"], self.cache[li]["conv"]
                )
                self.cache[li] = {"h": h, "conv": conv}
                x = x + y
            self.store.prefetch(li + 1)     # before the FFN/grouped launch
            if self.pages is not None:
                self.pages.prefetch(li + 1)  # next layer's host KV frames
            if ffn == "moe":
                if predictive:
                    x = x + self._expert_stage_predictive(li, x)
                else:
                    x = x + self._expert_stage(li, p, x)
            elif cfg.d_ff > 0 and "ffn" in p:
                x = x + _ffn_module(cfg, p, x)
        return _head_module(cfg, cfg.tie_embeddings, self.store.base, x)

    # -- module stages ---------------------------------------------------
    def _attention_stage(self, li, p, x, pos, row0: int = 0,
                         pos_host=None) -> jax.Array:
        """Micro-batched attention with the ω host/device split.

        The first ``round(ω·B)`` sequences of the FULL batch take the host
        path.  A micro-batch straddling that boundary is split at it, so
        the realized host fraction is exactly ``round(ω·B)/B`` instead of
        silently rounding a whole micro-batch onto the device path.

        The cache buffers are threaded through the donated row-block
        modules — each micro-batch's rows are updated in place; no
        whole-cache functional copy is made.
        """
        if self.pages is not None and not self.pages.fully_resident:
            return self._paged_attention_stage(li, p, x, pos, row0, pos_host)
        cfg, plan = self.cfg, self.plan
        n = x.shape[0]
        B = self._batch or n
        n_host = int(round(plan.omega * B))
        outs = []
        b_a = max(1, min(plan.b_a, n))
        k, v = self.cache[li]["k"], self.cache[li]["v"]
        lo, end = row0, row0 + n
        while lo < end:
            hi = min(end, lo + b_a)
            if lo < n_host < hi:
                hi = n_host                    # split the straddling batch
            fn = (
                _attn_decode_host_module if hi <= n_host
                else _attn_decode_module
            )
            # eager basic slicing uploads its start indices as int32
            # scalars (jax dispatches slice_p as dynamic_slice) — a
            # planned, bounded per-micro-batch transfer
            with sanitizer.allowed("decode-row-slice"):
                mb_x = x[lo - row0:hi - row0]
                mb_pos = pos if pos.ndim == 0 else pos[lo - row0:hi - row0]
            y, k, v = fn(cfg, lo, p, mb_x, k, v, mb_pos)
            outs.append(y)
            self.stats.attn_microbatches += 1
            if hi <= n_host:
                self.stats.host_attn_tokens += hi - lo
            else:
                self.stats.device_attn_tokens += hi - lo
            lo = hi
        self.cache[li]["k"], self.cache[li]["v"] = k, v
        return jnp.concatenate(outs, axis=0)

    @hot_path
    def _paged_attention_stage(self, li, p, x, pos, row0: int = 0,
                               pos_host=None) -> jax.Array:
        """Mode B decode attention (host-tier pages present).

        The ω MATH-path split is unchanged from ``_attention_stage`` — rows
        ``[0, round(ω·B))`` run the host-attention mechanism, the rest the
        device mechanism — so paged decode stays token-identical to the
        contiguous engine.  Page placement only decides where the KV BYTES
        live: device rows gather their span from the device pool plus the
        layer's streamed host frames in ONE launch (the htod copy prefetched
        a layer ahead, like streamed weights); host rows assemble their
        pages host-side and mirror their written slot back into whichever
        tier owns the written page.
        """
        cfg, plan = self.cfg, self.plan
        pages = self.pages
        n = x.shape[0]
        B = self._batch or n
        n_host_rows = int(round(plan.omega * B))
        if pos_host is None:                # direct call; planned readback
            with sanitizer.allowed("decode-pos-host-mirror"):
                pos_host = np.asarray(pos, np.int32)  # lint: allow[MG101] planned once-per-tick position readback for the page table
        pos_np = np.broadcast_to(
            np.atleast_1d(np.asarray(pos_host, np.int32)), (n,)  # lint: allow[MG101] pos_host is already a numpy mirror; host-only dtype/shape normalization
        )
        span, pt = pages.span, pages.page_tokens
        if cfg.sliding_window:
            wslot = pos_np % span
        else:
            wslot = np.minimum(pos_np, span - 1)
        wpage = wslot // pt
        woff = wslot % pt
        rows_all = np.arange(row0, row0 + n)
        nh = int((rows_all < n_host_rows).sum())   # host rows form a prefix
        K, hd = cfg.num_kv_heads, cfg.head_dim
        outs = []
        if nh:
            with sanitizer.allowed("paged-host-rows"):
                gk = np.zeros((nh, span, K, hd), pages._dtype)
                gv = np.zeros_like(gk)
                for i in range(nh):
                    gk[i], gv[i] = pages.read_row(li, int(rows_all[i]), span)
                y_h, k_new_h, v_new_h = _paged_attn_host_module(
                    cfg, p, x[:nh], jnp.asarray(gk), jnp.asarray(gv),
                    jnp.asarray(pos_np[:nh]),
                )
                outs.append(y_h)
                k_np, v_np = np.asarray(k_new_h), np.asarray(v_new_h)  # lint: allow[MG101] host rows own the written slot; planned readback
                dev_writes = []
                for i in range(nh):
                    f = int(pages.page_map[int(rows_all[i]), int(wpage[i])])
                    if f >= pages.device_frames:
                        pages.write_host_slot(
                            li, f - pages.device_frames, int(woff[i]),
                            k_np[i], v_np[i],
                        )
                    elif f >= 0:        # ω row spilled onto a device frame
                        dev_writes.append((f, int(woff[i]), i))
                if dev_writes:
                    width = max(8, -(-len(dev_writes) // 8) * 8)
                    fr = np.full(width, pages.device_frames, np.int32)  # null
                    off = np.zeros(width, np.int32)
                    ksel = np.zeros((width, K, hd), k_np.dtype)
                    vsel = np.zeros_like(ksel)
                    for j, (f, o, i) in enumerate(dev_writes):
                        fr[j], off[j] = f, o
                        ksel[j], vsel[j] = k_np[i], v_np[i]
                    pk, pv = _paged_slot_write_module(
                        pages.pool_k[li], pages.pool_v[li],
                        jnp.asarray(fr), jnp.asarray(off),
                        jnp.asarray(ksel), jnp.asarray(vsel),
                    )
                    pages.pool_k[li], pages.pool_v[li] = pk, pv
            self.stats.attn_microbatches += 1
            self.stats.host_attn_tokens += nh
        nd = n - nh
        if nd:
            didx = [int(r) for r in rows_all[nh:]]
            wframe, host_writes = pages.write_targets(didx, wpage[nh:])
            with sanitizer.allowed("paged-index-upload"):
                frames = jnp.asarray(pages.gather_indices(didx))
                posd = jnp.asarray(pos_np[nh:])
                wpaged = jnp.asarray(wpage[nh:])
                wframed = jnp.asarray(wframe)
            with sanitizer.allowed("decode-row-slice"):
                xd = x[nh:]
            ek, ev = pages.acquire(li)
            y_d, pk, pv, k_new, v_new = _paged_attn_decode_module(
                cfg, span, p, xd, pages.pool_k[li], pages.pool_v[li],
                ek, ev, frames, posd, wpaged, wframed,
            )
            pages.pool_k[li], pages.pool_v[li] = pk, pv
            if host_writes:             # device row's written page is host-side
                with sanitizer.allowed("paged-host-writeback"):
                    k_np, v_np = np.asarray(k_new), np.asarray(v_new)  # lint: allow[MG101] written page lives on the host tier; planned readback
                    for i, hf in host_writes:
                        pages.write_host_slot(
                            li, hf, int(woff[nh + i]), k_np[i], v_np[i]
                        )
            outs.append(y_d)
            self.stats.attn_microbatches += 1
            self.stats.device_attn_tokens += nd
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

    def _expert_stage(self, li, p, x) -> jax.Array:
        if self.expert_path == "grouped":
            return self._expert_stage_grouped(li, p, x)
        return self._expert_stage_loop(p, x)

    def _expert_stage_grouped(self, li, p, x) -> jax.Array:
        """One grouped-dispatch launch for the whole MoE stage: routing,
        gather, expert FFNs and combine all stay on device (§4.2 realized
        as a single module launch instead of a host-scheduled expert loop).
        A mesh engine routes the same stage through the collective dispatch
        (``repro.distributed.ep_engine``) — counters keep one meaning."""
        if self.sctx is not None:
            from repro.distributed.ep_engine import ep_expert_stage

            y, kept, dropped, load, nbytes = ep_expert_stage(self, li, p, x)
            self.stats.a2a_bytes += nbytes
            self.stats.collective_dispatches += 1
        else:
            y, kept, dropped, load = _grouped_expert_module(
                self.cfg, p, x, self._expert_capacity(x.shape[0])
            )
        self.stats.expert_launches += 1
        j = self._moe_index[li]
        self._kept_dev = self._kept_dev + kept
        self._dropped_dev_l[j] = self._dropped_dev_l[j] + dropped
        self._load_dev_l[j] = self._load_dev_l[j] + load
        return y

    def _next_streamed_moe(self, li: int) -> int:
        """The next MoE layer (wrapping) whose experts are streamed — the
        prediction target for layer ``li``'s gate tap.  Its norm2/router
        live in the store's pinned ``moe_shared`` set, so scoring it needs
        no expert bytes."""
        streamed = [l for l in self._moe_layers
                    if self.store.streams_experts(l)]
        pos = streamed.index(li)
        return streamed[(pos + 1) % len(streamed)]

    @hot_path
    def _expert_stage_predictive(self, li, x) -> jax.Array:
        """Predictive-streamed MoE stage: route + predict in ONE launch,
        read the packed (used-counts ++ predicted-ids) vector back under a
        single planned transfer, assemble only the USED experts' stacks
        (LRU/prefetch hits are free; mispredictions demand-fetch), issue
        the next streamed MoE layer's predicted prefetch, then run the
        grouped FFN.  Prediction moves WHEN bytes move, never WHICH math
        runs — the dispatch consumes the true routing, so output is
        bit-identical to the whole-stack path for any predictor."""
        cfg = self.cfg
        E = cfg.num_experts
        shared = self.store.moe_shared(li)
        nli = self._next_streamed_moe(li)
        khat = self.store.predict_topk
        h, gates, idx, packed = _route_predict_module(
            cfg, khat, shared["norm2"], shared["router"],
            self.store.moe_shared(nli)["router"], x,
        )
        with sanitizer.allowed("expert-prefetch"):
            packed_np = np.asarray(packed)  # lint: allow[MG101] ONE planned readback per predictive MoE layer: routed-copy counts + predicted ids
        used = np.nonzero(packed_np[:E])[0]
        if self.predictor is not None:      # test seam: prefetch-only
            pred = np.asarray(list(self.predictor(nli, khat)), np.int64)  # lint: allow[MG101] host-list coercion of the injected predictor's ids, no device buffer involved
        else:
            pred = packed_np[E:]
        wg, wu, wd = self.store.acquire_experts(li, used)
        self.store.prefetch_experts(nli, pred)
        y, kept, dropped, load = _grouped_ffn_module(
            cfg, self._expert_capacity(x.shape[0]), h, gates, idx,
            wg, wu, wd,
        )
        self.stats.expert_launches += 1
        j = self._moe_index[li]
        self._kept_dev = self._kept_dev + kept
        self._dropped_dev_l[j] = self._dropped_dev_l[j] + dropped
        self._load_dev_l[j] = self._load_dev_l[j] + load
        return y

    def _expert_stage_loop(self, p, x) -> jax.Array:
        """Sequential per-expert execution (the seed path, kept as the test
        oracle).  Chunks each expert's gathered tokens by b_e; syncs routing
        to the host every step — the launch pathology the grouped path
        removes."""
        cfg, plan = self.cfg, self.plan
        moe = p["moe"]
        h = _norm2_module(cfg, p, x)
        gates, idx, _ = _router_module(cfg, moe["router"], h)
        with sanitizer.allowed("expert-loop-oracle"):
            idx_np = np.asarray(idx)                 # host-side scheduling
            gates_np = np.asarray(gates)
            y = jnp.zeros_like(x)
            b_e = max(1, plan.b_e)
            for e in range(cfg.num_experts):
                rows, which = np.nonzero(idx_np == e)
                if rows.size == 0:
                    continue
                w = gates_np[rows, which]
                for lo in range(0, rows.size, b_e):
                    r = rows[lo : lo + b_e]
                    g = w[lo : lo + b_e]
                    ye = _expert_module(
                        moe["experts_w_gate"][e],
                        moe["experts_w_up"][e],
                        moe["experts_w_down"][e],
                        h[r],
                    )
                    y = y.at[r].add(
                        ye * jnp.asarray(g)[:, None].astype(ye.dtype)
                    )
                    self.stats.expert_launches += 1
                    self.stats.expert_tokens += int(r.size)
        return y

    # -- chunked decode ---------------------------------------------------
    def decode_chunk(self, tokens, pos, sampler, T: int,
                     live=None) -> jax.Array:
        """``T`` decode ticks for the full batch, sampled per slot; returns
        the ``(B, T)`` token matrix (column *t* is tick *t*'s tokens, fed
        back as tick *t+1*'s input).

        Fused one-launch path when ``fused_eligible()``: device rows ride
        ONE donated ``_fused_decode_chunk`` launch; the ω host-attention
        rows ``[0, round(ω·B))`` decode per-module OUTSIDE the launch
        (rows are independent, so the split is exact up to expert-capacity
        drops, which are per-dispatch).  Otherwise every row takes the
        per-module path, one tick at a time.  Positions are clamped at
        ``max_seq - 1`` exactly like the scheduler's per-tick clamp.

        ``live`` (B,) bool marks rows owned by unfinished requests (None =
        all).  Dead rows re-feed their stale token/position every tick —
        matching per-tick stepping, where the scheduler never updates a
        free slot — so chunked decode is tick-identical to per-tick decode
        even when expert-capacity drops couple rows through the grouped
        dispatch.  Both paths are token-for-token identical
        (property-tested).
        """
        stale = self._stale_snapshot()
        with sanitizer.allowed("decode-inputs"):
            tokens = jnp.asarray(tokens)
            pos = jnp.asarray(pos, jnp.int32)
            live = None if live is None else jnp.asarray(live, bool)
        with sanitizer.decode_region():
            out = self._decode_chunk_guarded(tokens, pos, sampler, T, live)
        self._poison_stale(stale)
        return out

    @hot_path
    def _decode_chunk_guarded(self, tokens, pos, sampler, T: int,
                              live=None) -> jax.Array:
        B = tokens.shape[0]
        if not (self.fused_eligible() and self.cache is not None):
            return self._chunk_rows_per_module(tokens, pos, sampler, T, 0, B,
                                               live)
        n_host = int(round(self.plan.omega * B))
        if n_host >= B:
            return self._chunk_rows_per_module(tokens, pos, sampler, T, 0, B,
                                               live)
        host_cols = None
        if n_host:
            # host-path rows first: their per-module modules update cache
            # rows [0, n_host) before the fused launch donates the buffers
            host_cols = self._chunk_rows_per_module(
                tokens, pos, sampler, T, 0, n_host, live
            )
        n = B - n_host
        posv = jnp.broadcast_to(jnp.atleast_1d(pos), (B,)).astype(jnp.int32)
        with sanitizer.allowed("decode-inputs"):
            livev = (jnp.ones((B,), bool) if live is None
                     else jnp.asarray(live, bool))
        idx = np.arange(n_host, B)
        with sanitizer.allowed("sampler-state"):
            keys, steps, temps, topks = sampler.state(idx)
            keys_d, steps_d = jnp.asarray(keys), jnp.asarray(steps)
            temps_d, topks_d = jnp.asarray(temps), jnp.asarray(topks)
        use_topk = bool((topks > 0).any())
        greedy_only = not bool((temps > 0).any())
        capacity = self._expert_capacity(n)
        cap = self.max_seq - 1
        key = (n, n_host, T, capacity, cap, use_topk, greedy_only)
        if self._fused_keys.add(key):
            self.stats.decode_retraces += 1
        with sanitizer.allowed("decode-row-slice"):
            toks_d, posv_d = tokens[n_host:], posv[n_host:]
            livev_d = livev[n_host:]
        toks, cache, kept, dropped, load = _fused_decode_chunk(
            self.cfg, tuple(self.schema), self.cfg.tie_embeddings, capacity,
            n_host, cap, use_topk, greedy_only, T,
            self.store.base, self._fused_layer_params(),
            toks_d, posv_d, livev_d, tuple(self.cache),
            keys_d, steps_d, temps_d, topks_d,
        )
        self.cache = list(cache)
        self._kept_dev = self._kept_dev + kept
        self._dropped_chunk_dev = self._dropped_chunk_dev + dropped
        self._load_chunk_dev = self._load_chunk_dev + load
        sampler.advance(idx, T)
        self.stats.fused_dispatches += 1
        self.stats.fused_ticks += T
        # the fused launch bundles the per-module work units into one
        # dispatch — keep their accounting equivalent to the per-module
        # path: one grouped-dispatch evaluation per MoE layer per tick,
        # and every fused row is a device-path attention token per attn
        # layer per tick (host rows were counted by their per-module pass)
        self.stats.expert_launches += T * sum(
            1 for _, f in self.schema if f == "moe"
        )
        self.stats.device_attn_tokens += n * T * sum(
            1 for k, _ in self.schema if k == "attn"
        )
        if host_cols is None:
            return toks
        return jnp.concatenate([host_cols, toks], axis=0)

    @hot_path
    def _chunk_rows_per_module(self, tokens, pos, sampler, T: int,
                               lo: int, hi: int, live=None) -> jax.Array:
        """Per-module chunk fallback over batch rows ``[lo, hi)``: ``T``
        sequential decode ticks, each sampled through the caller's
        ``BatchSampler`` (the streamed / loop-path / host-row execution).
        Dead rows (``live`` False) hold their stale token/position, like
        per-tick stepping.

        The per-tick position advance is HOST math: mixing the Python tick
        index into device arithmetic (``posr + t``) was an implicit scalar
        h2d transfer every tick — the exact pathology the sanitizer exists
        to catch.  Instead a numpy mirror advances on the host and ONE
        planned (n,)-vector upload per tick feeds the modules; the uploaded
        aval (int32, same shape) is identical, so trace keys are unchanged.
        The mirror also rides down to Mode B paging as ``pos_host``."""
        slots = np.arange(lo, hi)
        with sanitizer.allowed("decode-row-slice"):
            cur = tokens[lo:hi]
            posr = pos if pos.ndim == 0 else pos[lo:hi]
            lv = None if live is None else jnp.asarray(live, bool)[lo:hi]
        if lv is not None and posr.ndim == 0:
            posr = jnp.broadcast_to(posr, (hi - lo,))
        with sanitizer.allowed("decode-pos-host-mirror"):
            pos_np = np.asarray(posr, np.int32)  # lint: allow[MG101] one planned readback per chunk; host mirror drives tick advance
            adv_np = (None if lv is None
                      else np.asarray(lv, np.int32))  # lint: allow[MG101] live mask readback, once per chunk
        cap = self.max_seq - 1
        cols = []
        for t in range(T):
            pt_np = np.minimum(
                pos_np + (t if adv_np is None else t * adv_np), cap
            ).astype(np.int32)
            with sanitizer.allowed("decode-pos-upload"):
                pt = jnp.asarray(pt_np)
            lg = self._decode_rows(cur, pt, lo, pt_np)
            sampled = sampler.sample(lg, slots)
            cols.append(sampled)
            cur = sampled if lv is None else jnp.where(lv, sampled, cur)
        return jnp.stack(cols, axis=1)

    def decode_step_sampled(self, tokens: jax.Array, pos, sampler,
                            slots=None) -> jax.Array:
        """One decode tick plus on-device per-slot sampling: one fused
        launch when eligible (``decode_chunk`` with ``T=1``), else
        ``decode_step`` + a ``serving.sampling.BatchSampler`` launch (mixed
        greedy/temperature/top-k slots, seeded per slot — see that module's
        determinism contract).  Returns the (B,) next-token array instead
        of logits."""
        if slots is None and self.fused_eligible() and self.cache is not None:
            return self.decode_chunk(tokens, pos, sampler, 1)[:, 0]
        return sampler.sample(self.decode_step(tokens, pos), slots)

    # -- generation -------------------------------------------------------
    def generate(
        self, tokens: jax.Array, decode_len: int, frontend_emb=None,
        lengths=None, sampling=None, chunk: Optional[int] = None,
    ) -> jax.Array:
        """Generation — greedy by default (the paper's decoding strategy,
        §B); pass ``sampling`` (a ``serving.sampling.SamplingParams``) for
        seeded temperature / top-k decoding, applied uniformly with each
        batch row's index folded into its key (rows draw independent
        streams from one seed).

        ``lengths`` (B,) generates from a ragged right-padded batch: each
        sequence decodes at its own positions, token-for-token identical to
        generating it alone unpadded.

        Decode runs in fused multi-token chunks of ``chunk`` ticks
        (default: the plan's ``decode_chunk``) when the fused path is
        eligible — one device dispatch per chunk; the per-module fallback
        ticks through the same chunks one launch-set at a time, with
        identical tokens either way.
        """
        from repro.serving.sampling import BatchSampler

        B, S = tokens.shape
        sampler = BatchSampler.uniform(B, sampling)
        logits = self.prefill(tokens, frontend_emb, lengths=lengths)
        cols = [sampler.sample(logits)]
        base = S if lengths is None else jnp.asarray(lengths, jnp.int32)
        step = max(1, chunk if chunk is not None
                   else getattr(self.plan, "decode_chunk", 1))
        t, total = 0, decode_len - 1
        while t < total:
            Tc = min(step, total - t)
            mat = self.decode_chunk(
                cols[-1], jnp.asarray(base + t, jnp.int32), sampler, Tc
            )
            cols.extend(mat[:, j] for j in range(Tc))
            t += Tc
        result = jnp.stack(cols, axis=1)             # (B, decode_len)
        self.sync_stats()                            # fold device counters in
        return result
