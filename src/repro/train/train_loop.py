"""Training loop: jitted train_step with optional sharding, remat, ZeRO-1."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.sharding.specs import ShardCtx
from repro.train.optimizer import AdamWState, adamw_init, adamw_update


@dataclass
class TrainState:
    params: dict
    opt: AdamWState
    step: int = 0


def make_train_step(
    cfg: ModelConfig,
    ctx: ShardCtx = ShardCtx(),
    lr: float = 3e-4,
    remat: bool = True,
    aux_weight: float = 0.01,
    remat_policy: str = "full",
) -> Callable:
    """Returns train_step(params, opt, tokens, labels[, frontend_emb])."""

    def train_step(params, opt, tokens, labels, frontend_emb=None):
        def loss(p):
            return model_mod.loss_fn(
                cfg, p, tokens, labels, frontend_emb, ctx,
                remat=remat, aux_weight=aux_weight, remat_policy=remat_policy,
            )

        (total, (nll, aux)), grads = jax.value_and_grad(loss, has_aux=True)(
            params
        )
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        metrics = {"loss": total, "nll": nll, "aux": aux, "gnorm": gnorm}
        return params, opt, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    params,
    batches: Iterator[Tuple[jnp.ndarray, jnp.ndarray]],
    steps: int,
    ctx: ShardCtx = ShardCtx(),
    lr: float = 3e-4,
    log_every: int = 10,
    frontend_emb=None,
    checkpoint_path: Optional[str] = None,
    checkpoint_every: int = 0,
):
    """Simple synchronous training driver (examples/train_small.py)."""
    from repro.train.checkpoint import save_checkpoint

    step_fn = jax.jit(make_train_step(cfg, ctx, lr=lr))
    opt = adamw_init(params)
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        tokens, labels = next(batches)
        params, opt, metrics = step_fn(params, opt, tokens, labels, frontend_emb)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i + 1
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            print(
                f"step {i+1:5d} loss={m['loss']:.4f} nll={m['nll']:.4f} "
                f"aux={m['aux']:.4f} gnorm={m['gnorm']:.2f}"
            )
        if checkpoint_path and checkpoint_every and (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_path, params, step=i + 1)
    return params, opt, history
