from repro.train.optimizer import adamw_init, adamw_update
from repro.train.train_loop import TrainState, make_train_step, train_loop

__all__ = [
    "adamw_init",
    "adamw_update",
    "TrainState",
    "make_train_step",
    "train_loop",
]
