"""AdamW implemented directly on pytrees (no external optimizer dep)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p
    )
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), gnorm
