"""Flat-npz checkpointing for arbitrary param pytrees."""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(params) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[key + "::bf16"] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def save_checkpoint(path: str, params, step: int = 0) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)
    return path


def load_checkpoint(path: str, like) -> Tuple[dict, int]:
    """Restore into the structure of ``like`` (a template pytree)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    step = int(data["__step__"]) if "__step__" in data else 0
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_elems, leaf in leaves_with_path:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems
        )
        if key + "::bf16" in data:
            arr = jnp.asarray(data[key + "::bf16"]).astype(jnp.bfloat16)
        else:
            arr = jnp.asarray(data[key]).astype(leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
