"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5-0.5B family].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        arch_type="dense",
        source="hf:Qwen/Qwen1.5 (model card)",
        num_layers=40,
        d_model=2560,
        vocab_size=151_936,
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("qwen1.5-4b", full, smoke)
