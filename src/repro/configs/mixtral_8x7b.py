"""mixtral-8x7b — the paper's own primary evaluation model [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, MoE 8e top-2.
Used to validate EXPERIMENTS.md claims against the paper's tables.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        arch_type="moe",
        source="arXiv:2401.04088 (Mixtral of Experts)",
        num_layers=32,
        d_model=4096,
        vocab_size=32_000,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,
        num_experts=8,
        experts_per_token=2,
        moe_d_ff=14_336,
        sliding_window=0,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("mixtral-8x7b", full, smoke)
