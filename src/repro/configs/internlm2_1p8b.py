"""internlm2-1.8b — GQA [arXiv:2403.17297].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        arch_type="dense",
        source="arXiv:2403.17297 (InternLM2)",
        num_layers=24,
        d_model=2048,
        vocab_size=92_544,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("internlm2-1.8b", full, smoke)
