"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.  The EnCodec
conv/codec frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings of the right shape; we implement the decoder transformer.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        source="arXiv:2306.05284 (MusicGen)",
        num_layers=48,
        d_model=1536,
        vocab_size=2048,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        frontend="audio",
        frontend_tokens=256,    # conditioning frames supplied as embeddings
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("musicgen-medium", full, smoke)
