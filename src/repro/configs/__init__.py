from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    get_config,
    list_archs,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "list_archs",
]
