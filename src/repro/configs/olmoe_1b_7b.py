"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        arch_type="moe",
        source="arXiv:2409.02060 (OLMoE)",
        num_layers=16,
        d_model=2048,
        vocab_size=50_304,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=0,                  # every FFN is MoE
        num_experts=64,
        experts_per_token=8,
        moe_d_ff=1024,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("olmoe-1b-7b", full, smoke)
