"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        arch_type="dense",
        source="arXiv:2401.16818 (H2O-Danube)",
        num_layers=24,
        d_model=2560,
        vocab_size=32_000,
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        sliding_window=4096,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("h2o-danube-1.8b", full, smoke)
