"""internvl2-76b — InternViT + (Llama-3-70B-class) LLM [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The InternViT
vision encoder + MLP projector are a STUB: ``input_specs`` provides
precomputed patch embeddings; we implement the language backbone.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        arch_type="vlm",
        source="arXiv:2404.16821 (InternVL2)",
        num_layers=80,
        d_model=8192,
        vocab_size=128_256,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        rope_theta=500_000.0,
        frontend="vision",
        frontend_tokens=256,     # image patch tokens per sample
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("internvl2-76b", full, smoke)
