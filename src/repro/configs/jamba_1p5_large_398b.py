"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Attention appears once per 8 layers (offset 4); MoE every other layer.
The SSM blocks use our Mamba2/SSD formulation (see DESIGN.md §2: we
standardize all state-space blocks on SSD for a single well-tested kernel).
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type="hybrid",
        source="arXiv:2403.19887 (Jamba) / arXiv:2408.12570 (Jamba-1.5)",
        num_layers=72,
        d_model=8192,
        vocab_size=65_536,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24_576,
        num_experts=16,
        experts_per_token=2,
        moe_d_ff=24_576,
        moe_layer_period=2,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=256,
        attn_period=8,
        attn_offset=4,
    )


def smoke() -> ModelConfig:
    # one full interleave period (8 layers) at tiny width
    return reduce_for_smoke(full(), num_layers=8)


register("jamba-1.5-large-398b", full, smoke)
