"""Configuration system: model architectures and input shapes.

Every assigned architecture registers a ``ModelConfig`` here (full size) and
a reduced ``smoke()`` variant (<=2 layers, d_model<=512, <=4 experts) that is
actually executed on CPU in tests.  The full configs are exercised only via
the dry-run (ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description sufficient to build the model.

    The same dataclass describes dense, MoE, SSM, hybrid, VLM-backbone and
    audio-backbone architectures; unused blocks are disabled with zeros.
    """

    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                         # citation for the config
    num_layers: int
    d_model: int
    vocab_size: int

    # --- attention ---
    num_heads: int = 0                  # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False              # qwen-style
    sliding_window: int = 0             # 0 => full attention
    rope_theta: float = 10_000.0

    # --- dense FFN ---
    d_ff: int = 0                       # 0 => no dense FFN (pure-MoE / pure-SSM layer)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                   # per-expert hidden dim
    num_shared_experts: int = 0         # DeepSeek/Qwen-style always-on experts
    capacity_factor: float = 1.25
    moe_layer_period: int = 1           # MoE every Nth layer (jamba: 2)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0                  # d_state; 0 => no SSM layers
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid interleave (jamba): 1 attention layer per `attn_period` ---
    attn_period: int = 0                # 0 => homogeneous layers
    attn_offset: int = 0                # index of the attn layer within a period

    # --- modality frontend stub ---
    frontend: Optional[str] = None      # None | 'audio' | 'vision'
    frontend_tokens: int = 0            # prompt positions supplied as embeddings

    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when 500k-token decode is feasible (SSM / SWA / hybrid)."""
        if self.arch_type == "ssm":
            return True
        if self.arch_type == "hybrid":
            return True
        return self.sliding_window > 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i (hybrid interleave)."""
        if not self.has_ssm:
            return "attn"
        if not self.has_attention:
            return "ssm"
        assert self.attn_period > 0
        return "attn" if (i % self.attn_period) == self.attn_offset else "ssm"

    def ffn_kind(self, i: int) -> str:
        """'moe' or 'dense' for the FFN of layer i."""
        if not self.has_moe:
            return "dense"
        if (i % self.moe_layer_period) == (self.moe_layer_period - 1):
            return "moe"
        return "dense"

    # ---------------- parameter counting (for roofline 6ND) -----------
    def param_counts(self) -> Dict[str, int]:
        d = self.d_model
        counts: Dict[str, int] = {"embed": self.vocab_size * d}
        attn = moe = dense = ssm = norm = 0
        for i in range(self.num_layers):
            norm += 2 * d
            if self.layer_kind(i) == "attn":
                q = self.num_heads * self.head_dim
                kv = self.num_kv_heads * self.head_dim
                attn += d * q + 2 * d * kv + q * d
            else:
                di, ns = self.ssm_d_inner, self.ssm_state
                nh = self.ssm_nheads
                # in_proj (z, x, B, C, dt) + out_proj + conv + A,D
                attn_free = d * (2 * di + 2 * ns + nh) + di * d
                attn_free += self.ssm_conv_width * (di + 2 * ns) + 2 * nh
                ssm += attn_free
            if self.ffn_kind(i) == "moe":
                moe += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
                moe += self.num_shared_experts * 3 * d * self.moe_d_ff
            elif self.d_ff:
                dense += 3 * d * self.d_ff
        counts.update(attn=attn, moe=moe, dense_ffn=dense, ssm=ssm, norm=norm)
        if not self.tie_embeddings:
            counts["lm_head"] = self.vocab_size * d
        counts["total"] = sum(counts.values())
        # active params per token (MoE: only routed experts count)
        active = counts["total"] - counts["moe"]
        if self.has_moe:
            n_moe_layers = sum(
                1 for i in range(self.num_layers) if self.ffn_kind(i) == "moe"
            )
            per_layer = (self.experts_per_token + self.num_shared_experts) * (
                3 * self.d_model * self.moe_d_ff
            ) + self.d_model * self.num_experts
            active += n_moe_layers * per_layer
        counts["active"] = active
        return counts


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = [
    "mamba2-370m",
    "musicgen-medium",
    "olmoe-1b-7b",
    "internvl2-76b",
    "h2o-danube-1.8b",
    "internlm2-1.8b",
    "qwen1.5-4b",
    "qwen2-1.5b",
    "jamba-1.5-large-398b",
    "phi3.5-moe-42b-a6.6b",
    # the paper's own evaluation model family
    "mixtral-8x7b",
]


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def _ensure_loaded() -> None:
    if len(_REGISTRY) >= len(ARCH_IDS):
        return
    for arch in ARCH_IDS:
        mod = arch.replace("-", "_").replace(".", "p")
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return list(ARCH_IDS)


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Standard reduction used by the per-arch smoke variants."""
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = max(2, min(4, cfg.num_heads)) if cfg.num_heads else 0
    num_kv = 0
    if cfg.num_kv_heads:
        # preserve the GQA ratio where possible
        ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
        num_kv = max(1, num_heads // min(ratio, num_heads))
    kw = dict(
        num_layers=2 if not cfg.attn_period else cfg.attn_period,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.num_experts else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        frontend_tokens=min(cfg.frontend_tokens, 16) if cfg.frontend else 0,
        name=cfg.name + "-smoke",
    )
    kw.update(overrides)
    return replace(cfg, **kw)
