"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        arch_type="moe",
        source="hf:microsoft/Phi-3.5-MoE-instruct (model card)",
        num_layers=32,
        d_model=4096,
        vocab_size=32_064,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,                  # every FFN is MoE
        num_experts=16,
        experts_per_token=2,
        moe_d_ff=6400,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("phi3.5-moe-42b-a6.6b", full, smoke)
