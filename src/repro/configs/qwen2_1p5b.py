"""qwen2-1.5b — GQA, QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        arch_type="dense",
        source="arXiv:2407.10671 (Qwen2)",
        num_layers=28,
        d_model=1536,
        vocab_size=151_936,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("qwen2-1.5b", full, smoke)
