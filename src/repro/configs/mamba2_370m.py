"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024 attention-free, vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, reduce_for_smoke, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        arch_type="ssm",
        source="arXiv:2405.21060 (Mamba2 / SSD)",
        num_layers=48,
        d_model=1024,
        vocab_size=50_280,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,                 # Mamba2 blocks have no separate FFN
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=256,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return reduce_for_smoke(full())


register("mamba2-370m", full, smoke)
