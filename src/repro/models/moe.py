"""Sparse MoE layer: top-k router, expert FFNs, expert-parallel execution.

Two execution paths share the same parameters:

* ``moe_apply_local``   — exact dense-combine reference: every expert runs on
                           every token, outputs combined by the routing mask.
                           Used on one device (smoke tests, the CPU engine)
                           and as the oracle for the sharded/capacity path.
* ``moe_apply_sharded`` — expert-parallel ``shard_map``: tokens replicated
                           across the model axis, each rank dispatches to its
                           local experts with a capacity buffer (scatter),
                           runs the grouped expert GEMM, combines, and
                           ``psum``s over the model axis.

The capacity-based dispatch mirrors the paper's planner assumption of evenly
distributed tokens per expert (MoE-Gen §4.2 "Sequential execution of
experts"); the capacity factor bounds worst-case memory exactly like the
paper bounds ``b_e`` to prevent OOM.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.specs import ShardCtx, shard_map


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_moe_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "experts_w_gate": dense_init(ks[1], (e, d, f), in_dim=d, dtype=dt),
        "experts_w_up": dense_init(ks[2], (e, d, f), in_dim=d, dtype=dt),
        "experts_w_down": dense_init(ks[3], (e, f, d), in_dim=f, dtype=dt),
    }


def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """Top-k routing.  x: (..., D).  Returns (gates, idx, probs)."""
    logits = x.astype(jnp.float32) @ router_w               # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balance_loss(cfg: ModelConfig, probs: jax.Array, idx: jax.Array):
    """Switch-style auxiliary load-balancing loss."""
    e = cfg.num_experts
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    counts = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    return e * jnp.sum(me * frac)


def expert_ffn(wg, wu, wd, h):
    """Grouped expert FFN.  h: (E, C, D) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", h, wg)
    u = jnp.einsum("ecd,edf->ecf", h, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


# ---------------------------------------------------------------------------
# Grouped dispatch: capacity-bucketed gather -> one launch -> scatter-add
# ---------------------------------------------------------------------------
def _arrival_slots(ids: jax.Array, n_buckets: int, mask=None) -> jax.Array:
    """Slot of each routed copy within its bucket, in arrival order — the
    cumsum-of-one-hot core shared by every capacity-dispatch path (grouped,
    sharded psum, all-to-all).  Entries with ``mask=False`` consume no slot."""
    onehot = jax.nn.one_hot(ids, n_buckets, dtype=jnp.int32)
    if mask is not None:
        onehot = onehot * mask[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(pos, ids[:, None], axis=1)[:, 0]


def grouped_dispatch(
    cfg: ModelConfig,
    xt: jax.Array,          # (T, D) tokens
    gates: jax.Array,       # (T, k)
    idx: jax.Array,         # (T, k) expert ids
    wg, wu, wd,             # (E, ·, ·) expert weights
    capacity: int,
    use_kernel=None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The engine's expert module (paper §4.2), fully on device.

    Routed token copies are gathered into an ``(E, C, D)`` capacity buffer,
    pushed through ONE grouped FFN launch (``kernels.ops.grouped_expert_ffn``:
    Pallas on TPU, XLA einsum elsewhere), and scatter-added back weighted by
    their gates.  ``capacity`` is the per-expert token budget ``b_e``; routed
    copies beyond it are dropped (zero contribution), which the caller
    accounts for.  Returns ``(y, kept, dropped, load)`` — ``kept``/
    ``dropped`` device scalars plus ``load``, the (E,) per-expert routed-copy
    histogram counted BEFORE capacity drops (what the planner's measured
    ``b_e`` search consumes) — no host sync happens here.
    """
    from repro.kernels import ops as kernel_ops

    T, D = xt.shape
    E = cfg.num_experts
    k = cfg.experts_per_token
    flat_idx = idx.reshape(-1)                              # (T*k,)
    flat_gate = gates.reshape(-1)
    slot = _arrival_slots(flat_idx, E)
    keep = slot < capacity
    slot_c = jnp.minimum(slot, capacity - 1)
    tok = jnp.arange(T * k) // k
    buf = jnp.zeros((E, capacity, D), xt.dtype)
    buf = buf.at[flat_idx, slot_c].add(
        xt[tok] * keep[:, None].astype(xt.dtype)
    )
    out = kernel_ops.grouped_expert_ffn(buf, wg, wu, wd, use_kernel=use_kernel)
    back = out[flat_idx, slot_c]                            # (T*k, D)
    back = back * (keep[:, None] * flat_gate[:, None]).astype(back.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[tok].add(back.astype(xt.dtype))
    kept = jnp.sum(keep.astype(jnp.int32))
    load = jnp.zeros((E,), jnp.int32).at[flat_idx].add(1)
    return y, kept, jnp.int32(T * k) - kept, load


def moe_apply_grouped(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    capacity: Optional[int] = None,
    use_kernel=None,
) -> Tuple[jax.Array, jax.Array]:
    """Grouped-dispatch MoE forward — the same implementation the engine's
    decode step uses, reachable from the reference forward via
    ``ShardCtx(moe_dispatch='grouped')`` so prefill and decode share one
    expert path.  ``capacity`` defaults to the planner-style
    ``moe_capacity`` bound (``capacity_factor`` headroom over the balanced
    load), so routed copies beyond it are dropped under imbalance; the
    kept/dropped counters are NOT surfaced here — callers needing drop
    accounting (the engine's decode stage) call ``grouped_dispatch``
    directly.  See ROADMAP "Grouped prefill by default"."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates, idx, probs = route(cfg, p["router"], xt)
    cap = capacity if capacity is not None else moe_capacity(cfg, xt.shape[0])
    y, _, _, _ = grouped_dispatch(
        cfg, xt, gates, idx,
        p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"],
        cap, use_kernel=use_kernel,
    )
    return y.reshape(B, S, D).astype(x.dtype), load_balance_loss(cfg, probs, idx)


def predict_experts(
    cfg: ModelConfig, next_router_w: jax.Array, x: jax.Array, khat: int
) -> jax.Array:
    """Predict the NEXT MoE layer's active expert set from the current
    hidden state (device-computed; (khat,) int32 ids).

    Layer *l*'s post-mixer state pushed through layer *l+1*'s router is a
    strong proxy for *l+1*'s actual routing (PAPERS.md: predictive
    prefetching) because the residual stream changes slowly between
    adjacent layers.  Batch-aggregated: softmax probabilities are summed
    over tokens and the top-k-hat experts by expected load are returned —
    the set worth moving bytes for.  Predictions steer PREFETCH only; the
    actual routing at *l+1* fetches any mispredicted expert on demand."""
    logits = x.astype(jnp.float32) @ next_router_w          # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    scores = probs.reshape(-1, cfg.num_experts).sum(axis=0)
    _, ids = jax.lax.top_k(scores, min(khat, cfg.num_experts))
    return ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Exact local reference
# ---------------------------------------------------------------------------
def moe_apply_local(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Dense-combine MoE: exact, O(E * T * D * F) compute.  x: (B, S, D)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates, idx, probs = route(cfg, p["router"], xt)
    h = jnp.broadcast_to(xt[None], (cfg.num_experts,) + xt.shape)
    y_all = expert_ffn(
        p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"], h
    )                                                       # (E, T, D)
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)
    weight = jnp.einsum("tk,tke->te", gates, onehot)        # (T, E)
    y = jnp.einsum("te,etd->td", weight.astype(y_all.dtype), y_all)
    aux = load_balance_loss(cfg, probs, idx)
    return y.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Capacity-buffer dispatch (single rank's share of experts)
# ---------------------------------------------------------------------------
def _dispatch_combine(
    cfg: ModelConfig,
    xt: jax.Array,          # (T, D) local tokens
    gates: jax.Array,       # (T, k)
    idx: jax.Array,         # (T, k) global expert ids
    wg, wu, wd,             # (E_loc, ·, ·) this rank's experts
    e_lo: jax.Array,        # scalar: first global expert id on this rank
    capacity: int,
):
    T, D = xt.shape
    k = cfg.experts_per_token
    e_loc_n = wg.shape[0]
    flat_idx = idx.reshape(-1)                              # (T*k,)
    flat_gate = gates.reshape(-1)
    local_e = flat_idx - e_lo
    mine = (local_e >= 0) & (local_e < e_loc_n)
    local_e_c = jnp.clip(local_e, 0, e_loc_n - 1)
    slot = _arrival_slots(local_e_c, e_loc_n, mask=mine)
    keep = mine & (slot < capacity)
    slot_c = jnp.minimum(slot, capacity - 1)
    tok = jnp.arange(T * k) // k
    buf = jnp.zeros((e_loc_n, capacity, D), xt.dtype)
    contrib = xt[tok] * keep[:, None].astype(xt.dtype)
    buf = buf.at[local_e_c, slot_c].add(contrib)
    from repro.kernels import ops as kernel_ops

    out_buf = kernel_ops.grouped_expert_ffn(buf, wg, wu, wd)  # (E_loc, C, D)
    back = out_buf[local_e_c, slot_c]                       # (T*k, D)
    back = back * (keep[:, None] * flat_gate[:, None]).astype(back.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[tok].add(back.astype(xt.dtype))
    return y


def moe_capacity(cfg: ModelConfig, T: int) -> int:
    per = T * cfg.experts_per_token / max(cfg.num_experts, 1)
    c = int(per * cfg.capacity_factor) + 1
    return max(8, -(-c // 8) * 8)                           # round up to 8


def moe_apply_capacity_local(cfg, p, x):
    """Capacity-dispatch path on one device (oracle parity with sharded)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates, idx, probs = route(cfg, p["router"], xt)
    y = _dispatch_combine(
        cfg, xt, gates, idx,
        p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"],
        jnp.int32(0), moe_capacity(cfg, xt.shape[0]),
    )
    return y.reshape(B, S, D), load_balance_loss(cfg, probs, idx)


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path
# ---------------------------------------------------------------------------
def moe_apply_sharded(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, ctx: ShardCtx,
    small_batch_threshold: int = 4096,
) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism over the model axis.

    Tokens stay replicated across the model axis (sharded over batch axes);
    each model rank builds the capacity buffer for its experts, runs the
    grouped GEMM, and the partial outputs are summed with a psum — the
    collective pattern of tensor-parallel MoE.  If the expert count does not
    divide the model axis, experts are replicated and ranks split tokens
    instead (tensor-parallel experts are handled upstream by the sharding
    rules on the weight matrices + the local path).
    """
    if ctx.mesh is None or ctx.model_axis is None:
        return moe_apply_local(cfg, p, x)
    n_model = ctx.model_size
    E = cfg.num_experts
    if E % n_model != 0 and n_model % E != 0:
        # irregular ratio: tensor-parallel experts via XLA on the sharded
        # weight F dim (sharding rules place 'model' there in this case).
        return moe_apply_local(cfg, p, x)
    B, S, _ = x.shape
    if B * S * cfg.experts_per_token <= small_batch_threshold:
        # decode-scale batches: the dense einsum over the *stored* weight
        # sharding moves ZERO weight bytes (partial sums over the sharded
        # dims reduce activation-sized tensors instead) — the paper's
        # Table-9 small-batch regime.  At this T the all-expert compute is
        # negligible, while both shard_map paths would move weights
        # (91 GB/step on jamba-398B decode, measured in the dry-run).
        return moe_apply_local(cfg, p, x)

    B, S, D = x.shape
    batch_spec = ctx.spec("batch", None, None, shape=x.shape)
    model = ctx.model_axis
    # E >= n_model: each rank owns E/n_model experts.
    # E <  n_model: each expert is replicated n_model/E times and the
    # replicas split the token stream (capacity divides accordingly).
    n_rep = max(1, n_model // E)
    expert_spec = P(model, None, None) if n_rep == 1 else P(None, None, None)

    def body(xl, router_w, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        xt = xl.reshape(-1, D)
        gates, idx, probs = route(cfg, router_w, xt)
        rank = jax.lax.axis_index(model)
        cap = moe_capacity(cfg, xt.shape[0])
        if n_rep == 1:
            e_loc_n = wg.shape[0]
            e_lo = rank * e_loc_n
            y = _dispatch_combine(cfg, xt, gates, idx, wg, wu, wd, e_lo, cap)
        else:
            my_expert = rank % E
            replica = rank // E
            # keep only my replica's token share for my expert
            tok = jnp.arange(xt.shape[0] * cfg.experts_per_token) \
                // cfg.experts_per_token
            share = (tok % n_rep) == replica
            gates_m = jnp.where(
                share.reshape(gates.shape), gates, 0.0
            )
            idx_m = jnp.where(
                share.reshape(idx.shape), idx, -1
            )
            cap = max(8, -(-cap // n_rep))
            pick = lambda w: jax.lax.dynamic_index_in_dim(
                w, my_expert, 0, keepdims=True
            )
            y = _dispatch_combine(
                cfg, xt, gates_m, idx_m,
                pick(wg), pick(wu), pick(wd),
                my_expert, cap,
            )
        y = jax.lax.psum(y, model)
        aux = load_balance_loss(cfg, probs, idx)
        if ctx.batch_axes:
            aux = jax.lax.pmean(aux, ctx.batch_axes)
        return y.reshape(Bl, Sl, D), aux

    y, aux = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            batch_spec,
            P(),                       # router replicated
            expert_spec,
            expert_spec,
            expert_spec,
        ),
        out_specs=(batch_spec, P()),
        check_vma=False,
    )(x, p["router"], p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"])
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# All-to-all dispatch (beyond-paper: tokens sharded over the model axis too)
# ---------------------------------------------------------------------------
def moe_apply_a2a(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, ctx: ShardCtx
) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism with all-to-all token exchange.

    Unlike ``moe_apply_sharded`` (tokens replicated over the model axis,
    combined with a psum of the full activation), tokens here are sharded
    over the model axis as well: each rank routes only its own T/n tokens
    and ships each routed copy once to the rank owning its expert —
    k*T*D/n bytes each way instead of the psum's 2*T*D, and 1/n of the
    routing + dispatch work.  Requires E % n_model == 0 and the flattened
    token count divisible by n_model.
    """
    n_model = ctx.model_size
    E = cfg.num_experts
    B, S, D = x.shape
    T = B * S
    if (
        ctx.mesh is None or ctx.model_axis is None or n_model == 1
        or E % n_model != 0 or T % n_model != 0
    ):
        return moe_apply_sharded(cfg, p, x, ctx)

    model = ctx.model_axis
    batch_spec = ctx.spec("batch", None, None, shape=x.shape)
    e_loc_n = E // n_model

    def body(xl, router_w, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        xt = xl.reshape(-1, D)                       # (T_r, D) my tokens
        T_r = xt.shape[0]
        k = cfg.experts_per_token
        gates, idx, probs = route(cfg, router_w, xt)
        flat_idx = idx.reshape(-1)                   # (T_r*k,)
        dst = flat_idx // e_loc_n                    # destination rank
        # slot within my send-buffer page for rank `dst`
        slot = _arrival_slots(dst, n_model)
        cap = max(8, -(-int(T_r * k * cfg.capacity_factor) // n_model // 8) * 8)
        keep = slot < cap
        slot_c = jnp.minimum(slot, cap - 1)
        tok = jnp.arange(T_r * k) // k
        send = jnp.zeros((n_model, cap, D), xt.dtype)
        send = send.at[dst, slot_c].add(
            xt[tok] * keep[:, None].astype(xt.dtype)
        )
        # metadata rides along: local expert id (+1, 0 = empty slot)
        meta = jnp.zeros((n_model, cap), jnp.int32)
        meta = meta.at[dst, slot_c].add(
            jnp.where(keep, (flat_idx % e_loc_n) + 1, 0)
        )
        recv = jax.lax.all_to_all(send, model, 0, 0, tiled=True)
        meta_r = jax.lax.all_to_all(meta, model, 0, 0, tiled=True)
        # dispatch received tokens into per-expert capacity buffers
        h = recv.reshape(-1, D)                      # (n*cap, D)
        le = meta_r.reshape(-1)                      # 0 = empty
        valid = le > 0
        le0 = jnp.maximum(le - 1, 0)
        slot2 = _arrival_slots(le0, e_loc_n, mask=valid)
        cap2 = max(8, -(-n_model * cap // e_loc_n // 8) * 8)
        keep2 = valid & (slot2 < cap2)
        slot2_c = jnp.minimum(slot2, cap2 - 1)
        buf = jnp.zeros((e_loc_n, cap2, D), h.dtype)
        buf = buf.at[le0, slot2_c].add(
            h * keep2[:, None].astype(h.dtype)
        )
        out = expert_ffn(wg, wu, wd, buf)            # (E_loc, cap2, D)
        back = out[le0, slot2_c]                     # (n*cap, D)
        back = back * keep2[:, None].astype(back.dtype)
        back = back.reshape(n_model, cap, D)
        ret = jax.lax.all_to_all(back, model, 0, 0, tiled=True)
        # combine at home: gather each (t, k) copy from its send slot
        got = ret[dst, slot_c] * keep[:, None].astype(ret.dtype)
        got = got * gates.reshape(-1)[:, None].astype(got.dtype)
        y = jnp.zeros((T_r, D), xt.dtype).at[tok].add(got.astype(xt.dtype))
        aux = load_balance_loss(cfg, probs, idx)
        aux = jax.lax.pmean(aux, model)
        if ctx.batch_axes:
            aux = jax.lax.pmean(aux, ctx.batch_axes)
        return y.reshape(Bl, Sl, D), aux

    x_spec = ctx.spec("batch", "model", None, shape=x.shape)
    y, aux = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            x_spec,
            P(),
            P(model, None, None),
            P(model, None, None),
            P(model, None, None),
        ),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"])
    return y.astype(x.dtype), aux


def moe_apply(cfg: ModelConfig, p, x, ctx: ShardCtx = ShardCtx()):
    dispatch = getattr(ctx, "moe_dispatch", "psum")
    if ctx.mesh is not None and ctx.model_axis is not None:
        if dispatch == "grouped":
            raise ValueError(
                "moe_dispatch='grouped' is the single-device capacity path; "
                "use 'psum' or 'a2a' on a mesh with a model axis"
            )
        if dispatch == "a2a":
            return moe_apply_a2a(cfg, p, x, ctx)
        return moe_apply_sharded(cfg, p, x, ctx)
    if dispatch == "grouped":
        return moe_apply_grouped(
            cfg, p, x, capacity=getattr(ctx, "moe_capacity", None)
        )
    return moe_apply_local(cfg, p, x)
