"""Layer blocks: (attention | SSM) + (dense FFN | MoE) with pre-norm residuals."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import dense_init, rms_norm
from repro.sharding.specs import ShardCtx


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def init_ffn_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype=dt),
        "w_up": dense_init(ks[1], (d, f), dtype=dt),
        "w_down": dense_init(ks[2], (f, d), dtype=dt),
    }


def ffn_apply(p, x: jax.Array, ctx: ShardCtx = ShardCtx()) -> jax.Array:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    h = jax.nn.silu(g) * u
    h = ctx.shard(h, "batch", None, "model")
    return ctx.shard_residual(h @ p["w_down"])


# ---------------------------------------------------------------------------
# One layer
# ---------------------------------------------------------------------------
def init_layer_params(cfg: ModelConfig, kind: str, ffn_kind: str, key):
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.dtype)
    p: Dict = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attn_params(cfg, ks[0])
    else:
        p["ssm"] = ssm_mod.init_ssm_params(cfg, ks[0])
    if ffn_kind == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["moe"] = moe_mod.init_moe_params(cfg, ks[1])
    elif cfg.d_ff > 0:
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = init_ffn_params(cfg, ks[1])
    return p


def layer_forward(
    cfg: ModelConfig,
    kind: str,
    ffn_kind: str,
    p: Dict,
    x: jax.Array,
    ctx: ShardCtx = ShardCtx(),
    positions: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict, jax.Array]:
    """Full-sequence layer.  Returns (x, cache_entry, aux_loss).

    ``lengths`` (B,) enables ragged (right-padded) batches: the sequence
    mixers mask padded positions so valid positions and cached state are
    exactly what the unpadded sequences would produce.
    """
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        y, cache = attn_mod.attn_forward(cfg, p["attn"], h, ctx, positions,
                                         lengths)
    else:
        y, cache = ssm_mod.ssm_forward(cfg, p["ssm"], h, ctx, lengths)
    x = x + y
    if ffn_kind == "moe":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        y, aux = moe_mod.moe_apply(cfg, p["moe"], h, ctx)
        x = x + y
    elif cfg.d_ff > 0:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_apply(p["ffn"], h, ctx)
    return x, cache, aux


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind == "attn":
        return attn_mod.init_kv_cache(cfg, batch, max_seq)
    return ssm_mod.init_ssm_state(cfg, batch)


def layer_decode(
    cfg: ModelConfig,
    kind: str,
    ffn_kind: str,
    p: Dict,
    x: jax.Array,                  # (B, 1, D)
    cache: Dict,
    pos: jax.Array,
    ctx: ShardCtx = ShardCtx(),
) -> Tuple[jax.Array, Dict]:
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        y, cache = attn_mod.attn_decode(cfg, p["attn"], h, cache, pos, ctx)
    else:
        y, cache = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache, ctx)
    x = x + y
    if ffn_kind == "moe":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        y, _ = moe_mod.moe_apply(cfg, p["moe"], h, ctx)
        x = x + y
    elif cfg.d_ff > 0:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_apply(p["ffn"], h, ctx)
    return x, cache
