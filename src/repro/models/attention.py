"""Attention: GQA with RoPE; full-sequence (train/prefill) and decode paths.

Three implementations:

* ``naive_attention``     — materialized scores, used for tiny smoke shapes
                             and as the oracle for the blocked versions.
* ``blocked_attention``   — flash-style online-softmax over KV blocks,
                             memory-bounded; causal mask applied per block.
* ``swa_attention``       — sliding-window attention that only *computes*
                             the window (sub-quadratic): scans q blocks and
                             slices a static-size KV window per block.

Decode uses a pre-allocated KV cache (full attention) or a circular window
buffer (SWA).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init
from repro.sharding.specs import ShardCtx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_attn_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dt),
        "wo": dense_init(ks[3], (h * hd, d), in_dim=h * hd, dtype=dt),
    }
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((h * hd,), dt)
        p["wk_bias"] = jnp.zeros((kv * hd,), dt)
        p["wv_bias"] = jnp.zeros((kv * hd,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p, x: jax.Array):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["wq_bias"]
        k = k + p["wk_bias"]
        v = v + p["wv_bias"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math (all take (B, S, H, D) / (B, T, K, D))
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, K, G, D), k: (B, Sk, K, D) -> (B, K, G, Sq, Sk) in f32."""
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
    )


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference attention.  q: (B, Sq, H, D); k, v: (B, Sk, K, D).

    ``kv_mask`` (B, Sk) bool marks valid keys; padded positions of a ragged
    batch are masked out of every query's context.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = D ** -0.5
    qg = q.reshape(B, Sq, K, G, D)
    scores = _gqa_scores(qg, k) * scale                     # (B,K,G,Sq,Sk)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask, scores, NEG_INF)
    if kv_mask is not None:
        scores = jnp.where(kv_mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Flash-style attention: online softmax over KV blocks.

    Memory is O(S * kv_block) instead of O(S^2).  All KV blocks are computed
    and masked (the Pallas kernel skips fully-masked blocks on TPU; see
    kernels/flash_attention).  ``kv_mask`` (B, Sk) masks padded keys of a
    ragged batch.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if S % q_block or S % kv_block:
        return naive_attention(q, k, v, causal=causal, window=window,
                               kv_mask=kv_mask)
    scale = D ** -0.5
    nq, nk = S // q_block, S // kv_block
    qb = q.reshape(B, nq, q_block, K, G, D)

    def body(carry, i):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(k, i * kv_block, kv_block, axis=1)
        vs = lax.dynamic_slice_in_dim(v, i * kv_block, kv_block, axis=1)
        s = (
            jnp.einsum("bnqkgd,bjkd->bnkgqj", qb, ks,
                       preferred_element_type=jnp.float32)
            * scale
        )                                                   # (B,nq,K,G,qb,kb)
        qpos = (
            jnp.arange(nq)[:, None] * q_block + jnp.arange(q_block)[None, :]
        )                                                   # (nq, qb)
        kpos = i * kv_block + jnp.arange(kv_block)          # (kb,)
        mask = jnp.ones((nq, q_block, kv_block), bool)
        if causal:
            mask &= qpos[..., None] >= kpos[None, None, :]
        if window:
            mask &= qpos[..., None] - kpos[None, None, :] < window
        s = jnp.where(mask[:, None, None, :, :][None], s, NEG_INF)
        if kv_mask is not None:
            km = lax.dynamic_slice_in_dim(kv_mask, i * kv_block, kv_block,
                                          axis=1)         # (B, kb)
            s = jnp.where(km[:, None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bnkgqj,bjkd->bnkgqd", p.astype(vs.dtype), vs)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, K, G, q_block), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, K, G, q_block), jnp.float32)
    a0 = jnp.zeros((B, nq, K, G, q_block, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.astype(q.dtype).transpose(0, 1, 4, 2, 3, 5)   # (B,nq,qb,K,G,D)
    return out.reshape(B, S, H, D)


def swa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_block: int = 512,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Sliding-window attention computing only the window (sub-quadratic).

    Scans query blocks; each block attends to a static slice of
    ``window + q_block`` keys ending at the block's last position.  Ragged
    batches (``kv_mask``) fall back to the materialized reference: padded
    keys must be masked everywhere, and the paper's workloads pad the long
    sliding-window prompts to a uniform length anyway.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if kv_mask is not None or S <= window + q_block or S % q_block:
        return naive_attention(q, k, v, causal=True, window=window,
                               kv_mask=kv_mask)
    scale = D ** -0.5
    nq = S // q_block
    span = window + q_block
    # pad keys/values on the left so every slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def body(_, i):
        qs = lax.dynamic_slice_in_dim(q, i * q_block, q_block, axis=1)
        qs = qs.reshape(B, q_block, K, G, D)
        # in padded coords, query block [i*qb, i*qb+qb) sees keys
        # [i*qb, i*qb + span)  (= original [i*qb - window, i*qb + qb))
        ks = lax.dynamic_slice_in_dim(kp, i * q_block, span, axis=1)
        vs = lax.dynamic_slice_in_dim(vp, i * q_block, span, axis=1)
        s = _gqa_scores(qs, ks) * scale                     # (B,K,G,qb,span)
        qpos = i * q_block + jnp.arange(q_block)
        kpos = i * q_block + jnp.arange(span) - window      # original coords
        mask = (qpos[:, None] >= kpos[None, :]) & (
            qpos[:, None] - kpos[None, :] < window
        ) & (kpos[None, :] >= 0)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vs.dtype), vs)
        return None, o.reshape(B, q_block, H, D)

    _, outs = lax.scan(body, None, jnp.arange(nq))          # (nq,B,qb,H,D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def full_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Dispatcher used by the model for full-sequence passes."""
    S = q.shape[1]
    if window and S > window:
        return swa_attention(q, k, v, window=window, kv_mask=kv_mask)
    if S <= 1024:
        return naive_attention(q, k, v, causal=True, window=window,
                               kv_mask=kv_mask)
    return blocked_attention(q, k, v, causal=True, window=window,
                             kv_mask=kv_mask)


# ---------------------------------------------------------------------------
# Module-level forward passes
# ---------------------------------------------------------------------------
def attn_forward(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: ShardCtx = ShardCtx(),
    positions: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention.  Returns (output, kv) so prefill can cache.

    ``lengths`` (B,) marks the true length of each right-padded sequence:
    keys at padded positions are masked out of every query's context, so a
    ragged batch attends exactly what its unpadded sequences would.
    (Outputs *at* padded query positions are garbage — callers must never
    read them; the decode path masks them by per-sequence position.)

    Sharding: heads over the model axis when the head count divides it;
    otherwise *context parallelism* — queries shard over sequence, KV
    replicate — which keeps the S^2 work partitioned instead of silently
    replicating it (found via the dry-run roofline: 20/24/12-head archs on a
    16-way axis were 16x redundant).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    # rope BEFORE the sharding constraints: its f32 intermediates otherwise
    # get collected in f32 (2x collective bytes, seen in the dry-run HLO)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    msize = ctx.model_size
    heads_ok = msize <= 1 or cfg.num_heads % msize == 0
    if heads_ok:
        q = ctx.shard(q, "batch", None, "model", None)
        k = ctx.shard(k, "batch", None, "model", None)
        v = ctx.shard(v, "batch", None, "model", None)
    else:
        q = ctx.shard(q, "batch", "model", None, None)
        k = ctx.shard(k, "batch", None, None, None)
        v = ctx.shard(v, "batch", None, None, None)
    kv_mask = None
    if lengths is not None:
        kv_mask = jnp.arange(S)[None, :] < lengths[:, None]
    out = full_attention(q, k, v, window=cfg.sliding_window, kv_mask=kv_mask)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = out @ p["wo"]
    return ctx.shard_residual(y), {"k": k, "v": v}


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=None
) -> Dict[str, jax.Array]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    span = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (batch, span, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                       # (B, 1, D)
    cache: Dict[str, jax.Array],
    pos: jax.Array,                     # scalar or (B,) int32: current position
    ctx: ShardCtx = ShardCtx(),
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against a pre-allocated (possibly circular) cache.

    ``pos`` may be a scalar (uniform batch) or a per-sequence ``(B,)``
    vector (ragged batch / continuous scheduler): each sequence writes its
    new KV at its own position and attends only its own ``<= pos`` prefix,
    so padded or recycled cache rows beyond a sequence's length are never
    attended.
    """
    B = x.shape[0]
    K, hd = cfg.num_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(cfg, p, x)                       # (B,1,·,hd)
    posv = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (B,)
    )                                                       # (B,)
    posb = posv[:, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    span = cache["k"].shape[1]
    slot = jnp.where(
        cfg.sliding_window > 0, posv % span, jnp.minimum(posv, span - 1)
    )                                                       # (B,)
    rows = jnp.arange(B)
    ck = cache["k"].at[rows, slot].set(k[:, 0])
    cv = cache["v"].at[rows, slot].set(v[:, 0])

    G = cfg.num_heads // K
    qg = q.reshape(B, 1, K, G, hd)
    s = _gqa_scores(qg, ck) * (hd ** -0.5)                  # (B,K,G,1,span)
    idx = jnp.arange(span)
    valid = idx[None, :] <= posb                            # ring holds last W
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr.astype(cv.dtype), cv)
    o = o.reshape(B, 1, cfg.num_heads * hd)
    y = o @ p["wo"]
    return ctx.shard(y, "batch", None, None), {"k": ck, "v": cv}
