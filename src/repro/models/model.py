"""Composable decoder-only model covering all assigned architectures.

Layers are organized into *groups*: the smallest repeating pattern of
(sequence-mixer kind, FFN kind) pairs — a single layer for homogeneous
stacks, an 8-layer period for Jamba-style hybrids.  Parameters are stacked
over groups and the stack is traversed with ``lax.scan`` so the lowered HLO
stays one-group-sized regardless of depth (essential for the 80-layer
dry-runs).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.blocks import (
    init_layer_cache,
    init_layer_params,
    layer_decode,
    layer_forward,
)
from repro.models.layers import dense_init, rms_norm
from repro.sharding.specs import ShardCtx


def layer_pattern(cfg: ModelConfig) -> List[Tuple[str, str]]:
    g = cfg.attn_period if cfg.attn_period else 1
    if cfg.has_moe:
        g = math.lcm(g, cfg.moe_layer_period)
    assert cfg.num_layers % g == 0, (cfg.name, cfg.num_layers, g)
    pattern = [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(g)]
    for i in range(cfg.num_layers):
        assert (cfg.layer_kind(i), cfg.ffn_kind(i)) == pattern[i % g]
    return pattern


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(layer_pattern(cfg))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> Dict:
    pattern = layer_pattern(cfg)
    G = num_groups(cfg)
    dt = jnp.dtype(cfg.dtype)
    k_layers, k_embed, k_head = jax.random.split(key, 3)

    def init_group(k):
        sk = jax.random.split(k, len(pattern))
        return [
            init_layer_params(cfg, kind, ffn, sk[j])
            for j, (kind, ffn) in enumerate(pattern)
        ]

    layers = jax.vmap(init_group)(jax.random.split(k_layers, G))
    params = {
        "embed": dense_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype=dt),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype=dt
        )
    return params


def _embed(cfg: ModelConfig, params, tokens, frontend_emb, ctx: ShardCtx):
    x = jnp.take(params["embed"], tokens, axis=0)
    if frontend_emb is not None:
        F = frontend_emb.shape[1]
        x = jnp.concatenate([frontend_emb.astype(x.dtype), x[:, F:]], axis=1)
    return ctx.shard_residual(x)


def _logits(cfg: ModelConfig, params, x, ctx: ShardCtx):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return ctx.shard(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,                     # (B, S) int32
    frontend_emb: Optional[jax.Array] = None,
    ctx: ShardCtx = ShardCtx(),
    remat: bool = False,
    logits_mode: str = "full",             # full | last | none
    remat_policy: str = "full",            # full | dots
    lengths: Optional[jax.Array] = None,   # (B,) true lengths (ragged batch)
):
    """Returns (logits, aux_loss) — logits (B,S,V), (B,1,V), or final hidden.

    ``lengths`` marks the true length of each right-padded sequence.  Padded
    positions are masked out of attention and the SSM recurrence, and
    ``logits_mode='last'`` gathers each sequence's logits at its OWN last
    token instead of the batch's right edge (a pad position for every
    shorter prompt).
    """
    pattern = layer_pattern(cfg)
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, frontend_emb, ctx)
    positions = jnp.arange(S)[None, :]
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)

    def body(carry, group_p):
        x, aux = carry
        caches = []
        for j, (kind, ffn) in enumerate(pattern):
            x, cache, a = layer_forward(
                cfg, kind, ffn, group_p[j], x, ctx, positions, lengths
            )
            caches.append(cache)
            aux = aux + a
        return (x, aux), caches

    if remat and remat_policy == "dots":
        # save matmul outputs: the backward pass reuses them instead of
        # re-running the forward (and crucially, its collectives)
        scan_body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    elif remat:
        scan_body = jax.checkpoint(body)
    else:
        scan_body = body
    (x, aux), caches = lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    if logits_mode == "none":
        return x, aux, caches
    if logits_mode == "last":
        if lengths is not None:
            last = x[jnp.arange(B), lengths - 1][:, None]
        else:
            last = x[:, -1:]
        return _logits(cfg, params, last, ctx), aux, caches
    return _logits(cfg, params, x, ctx), aux, caches


def loss_fn(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    labels: jax.Array,
    frontend_emb: Optional[jax.Array] = None,
    ctx: ShardCtx = ShardCtx(),
    remat: bool = True,
    aux_weight: float = 0.01,
    vocab_chunk: int = 1024,
    remat_policy: str = "full",
):
    """Mean-token NLL with *chunked* vocabulary projection.

    The logits tensor (B, S, V) is never materialized: the final hidden
    states are scanned in sequence chunks, each chunk projected and reduced
    to per-token NLL immediately — essential at 128k+ vocabularies.
    """
    x, aux, _ = forward(
        cfg, params, tokens, frontend_emb, ctx, remat=remat,
        logits_mode="none", remat_policy=remat_policy,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    B, S, D = x.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    n_chunks = max(1, S // vocab_chunk) if S % vocab_chunk == 0 else 1
    c = S // n_chunks
    xc = x.reshape(B, n_chunks, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, c).transpose(1, 0, 2)

    def chunk_nll(carry, inp):
        xs, ls = inp
        lg = (xs @ head).astype(jnp.float32)
        lg = ctx.shard(lg, "batch", None, "model")
        lse = jax.nn.logsumexp(lg, axis=-1)
        lab = jnp.take_along_axis(lg, ls[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - lab), None

    body = jax.checkpoint(chunk_nll) if remat else chunk_nll
    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    nll = total / (B * S)
    return nll + aux_weight * aux, (nll, aux)


# ---------------------------------------------------------------------------
# Prefill: forward + cache extraction
# ---------------------------------------------------------------------------
def prefill(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    frontend_emb: Optional[jax.Array] = None,
    ctx: ShardCtx = ShardCtx(),
    lengths: Optional[jax.Array] = None,
):
    """Returns (last-token logits (B,1,V), caches).

    Attention cache entries come back as the raw per-layer K/V of shape
    (G, B, S, K, hd) (rope already applied); SSM entries as the final
    recurrent state.  ``serving.kvcache`` converts these into decode-ready
    buffers (padding / ring alignment).  ``lengths`` (B,) makes a ragged
    (right-padded) batch exact: pads are masked and logits come from each
    sequence's true last token.
    """
    logits, aux, caches = forward(
        cfg, params, tokens, frontend_emb, ctx, logits_mode="last",
        lengths=lengths,
    )
    return logits, caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> List:
    pattern = layer_pattern(cfg)
    G = num_groups(cfg)
    slots = []
    for kind, _ in pattern:
        c = init_layer_cache(cfg, kind, batch, max_seq)
        slots.append(
            jax.tree.map(lambda a: jnp.zeros((G,) + a.shape, a.dtype), c)
        )
    return slots


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: List,
    tokens: jax.Array,                 # (B,) int32
    pos: jax.Array,                    # scalar int32 current position
    ctx: ShardCtx = ShardCtx(),
):
    """One token for every sequence.  Returns (logits (B,V), new cache)."""
    pattern = layer_pattern(cfg)
    x = _embed(cfg, params, tokens[:, None], None, ctx)

    def body(x, xs):
        group_p, group_c = xs
        new_c = []
        for j, (kind, ffn) in enumerate(pattern):
            x, c = layer_decode(cfg, kind, ffn, group_p[j], x, group_c[j], pos, ctx)
            new_c.append(c)
        return x, new_c

    x, new_cache = lax.scan(body, x, (params["layers"], cache))
    logits = _logits(cfg, params, x, ctx)
    return logits[:, 0], new_cache
