"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Full-sequence processing uses the chunked SSD algorithm: quadratic
attention-like computation inside chunks of length ``ssm_chunk`` plus a
linear inter-chunk recurrence, giving O(S * Q) work and an O(1)-state decode
step.  This is the sub-quadratic path that makes ``long_500k`` feasible.

The chunk-local quadratic part is also implemented as a Pallas TPU kernel
(kernels/ssd_scan) with this file's ``_chunk_math`` as its oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.sharding.specs import ShardCtx


def init_ssm_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    d, di, ns, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    w = cfg.ssm_conv_width
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    ch = di + 2 * ns
    return {
        "wz": dense_init(ks[0], (d, di), dtype=dt),
        "wx": dense_init(ks[1], (d, di), dtype=dt),
        "wB": dense_init(ks[2], (d, ns), dtype=dt),
        "wC": dense_init(ks[3], (d, ns), dtype=dt),
        "wdt": dense_init(ks[4], (d, nh), dtype=dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(ks[5], (nh,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[6], (w, ch), in_dim=w, dtype=dt),
        "conv_b": jnp.zeros((ch,), dt),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[7], (di, d), dtype=dt),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  u: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    S = u.shape[1]
    out = sum(up[:, i : i + S] * w[i] for i in range(W))
    return out + b


def _proj_inputs(cfg: ModelConfig, p, x: jax.Array):
    z = x @ p["wz"]
    xs = x @ p["wx"]
    Bc = x @ p["wB"]
    Cc = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
    )                                                        # (B,S,nh) f32
    return z, xs, Bc, Cc, dt


def _chunk_math(x_c, B_c, C_c, dt_c, dA_c, H):
    """One SSD chunk.

    x_c: (Bt,Q,nh,hp); B_c/C_c: (Bt,Q,ns); dt_c/dA_c: (Bt,Q,nh) f32;
    H: (Bt,nh,ns,hp) f32 carried state.  Returns (Y_c, H_next).
    """
    cum = jnp.cumsum(dA_c, axis=1)                           # (Bt,Q,nh)
    # --- intra-chunk (quadratic within the chunk) ---
    diff = cum[:, :, None, :] - cum[:, None, :, :]           # (Bt,i,j,nh)
    Q = x_c.shape[1]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: above-diagonal diffs are positive and overflow,
    # which would poison the backward pass through the where (NaN * 0)
    diff = jnp.where(causal[None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    CB = jnp.einsum("bis,bjs->bij", C_c.astype(jnp.float32),
                    B_c.astype(jnp.float32))
    M = CB[:, :, :, None] * L * dt_c[:, None, :, :]          # (Bt,i,j,nh)
    Y_intra = jnp.einsum("bijn,bjnp->binp", M, x_c.astype(jnp.float32))
    # --- inter-chunk (incoming state) ---
    Y_inter = jnp.einsum(
        "bis,bnsp->binp", C_c.astype(jnp.float32), H
    ) * jnp.exp(cum)[..., None]
    # --- state update ---
    w = jnp.exp(cum[:, -1:, :] - cum) * dt_c                 # (Bt,Q,nh)
    S_c = jnp.einsum(
        "bjn,bjs,bjnp->bnsp", w, B_c.astype(jnp.float32),
        x_c.astype(jnp.float32),
    )
    H_next = H * jnp.exp(cum[:, -1])[:, :, None, None] + S_c
    return Y_intra + Y_inter, H_next


def ssd_scan(
    x: jax.Array,      # (B, S, nh, hp)
    B_in: jax.Array,   # (B, S, ns)
    C_in: jax.Array,   # (B, S, ns)
    dt: jax.Array,     # (B, S, nh) f32
    A: jax.Array,      # (nh,) f32, negative
    chunk: int,
    h0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y (B,S,nh,hp), final state (B,nh,ns,hp))."""
    Bt, S, nh, hp = x.shape
    ns = B_in.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nch = S // Q
    dA = dt * A                                              # (B,S,nh)

    xc = x.reshape(Bt, nch, Q, nh, hp).transpose(1, 0, 2, 3, 4)
    Bc = B_in.reshape(Bt, nch, Q, ns).transpose(1, 0, 2, 3)
    Cc = C_in.reshape(Bt, nch, Q, ns).transpose(1, 0, 2, 3)
    dtc = dt.reshape(Bt, nch, Q, nh).transpose(1, 0, 2, 3)
    dAc = dA.reshape(Bt, nch, Q, nh).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((Bt, nh, ns, hp), jnp.float32)

    def body(H, inputs):
        x_c, B_c, C_c, dt_c, dA_c = inputs
        Y, H_next = _chunk_math(x_c, B_c, C_c, dt_c, dA_c, H)
        return H_next, Y.astype(x.dtype)

    H_final, Ys = lax.scan(body, h0, (xc, Bc, Cc, dtc, dAc))
    y = Ys.transpose(1, 0, 2, 3, 4).reshape(Bt, S, nh, hp)
    return y, H_final


def ssm_forward(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    ctx: ShardCtx = ShardCtx(),
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence Mamba2 block.  Returns (y, state) for prefill caching.

    ``lengths`` (B,) handles right-padded ragged batches: ``dt`` is zeroed at
    padded positions, so the recurrence neither decays nor absorbs input
    there — the cached final state equals the state at each sequence's true
    length — and the conv tail is gathered at each sequence's own last
    ``W-1`` positions (zero where the sequence is shorter than the window,
    matching the reference's left zero-padding).
    """
    B, S, _ = x.shape
    di, ns, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xs, Bc, Cc, dt = _proj_inputs(cfg, p, x)
    # pin the head-parallel layout through the whole block: without these
    # constraints XLA re-gathers activations around the SSD einsums
    # (85 GB/step of dot_general all-gathers on jamba train, dry-run HLO)
    z = ctx.shard(z, "batch", None, "model")
    dt = ctx.shard(dt, "batch", None, "model")
    u = jnp.concatenate([xs, Bc, Cc], axis=-1)
    W = cfg.ssm_conv_width - 1
    if lengths is None:
        conv_tail = u[:, -W:, :]
    else:
        dt = dt * (jnp.arange(S)[None, :] < lengths[:, None])[..., None]
        tail_pos = lengths[:, None] - W + jnp.arange(W)[None, :]   # (B, W)
        conv_tail = jnp.take_along_axis(
            u, jnp.maximum(tail_pos, 0)[..., None], axis=1
        ) * (tail_pos >= 0)[..., None].astype(u.dtype)
    u = jax.nn.silu(_causal_conv(u, p["conv_w"], p["conv_b"]))
    xs, Bc, Cc = jnp.split(u, [di, di + ns], axis=-1)
    xs = ctx.shard(xs, "batch", None, "model")
    xh = xs.reshape(B, S, nh, hp)
    xh = ctx.shard(xh, "batch", None, "model", None)
    A = -jnp.exp(p["A_log"])
    y, H = ssd_scan(xh, Bc, Cc, dt, A, cfg.ssm_chunk)
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = ctx.shard(y, "batch", None, "model", None)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    y = ctx.shard(y, "batch", None, "model")
    out = y @ p["out_proj"]
    out = ctx.shard_residual(out)
    state = {"h": H, "conv": conv_tail}
    return out, state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    di, ns, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    return {
        "h": jnp.zeros((batch, nh, ns, hp), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * ns), dtype),
    }


def ssm_decode(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                      # (B, 1, D)
    state: Dict[str, jax.Array],
    ctx: ShardCtx = ShardCtx(),
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """O(1) decode step: recurrent SSM update."""
    B = x.shape[0]
    di, ns, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xs, Bc, Cc, dt = _proj_inputs(cfg, p, x)              # (B,1,·)
    u_t = jnp.concatenate([xs, Bc, Cc], axis=-1)             # (B,1,ch)
    win = jnp.concatenate([state["conv"], u_t], axis=1)      # (B,W,ch)
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xs, Bc, Cc = jnp.split(conv_out.astype(x.dtype), [di, di + ns], axis=-1)
    xh = xs.reshape(B, nh, hp).astype(jnp.float32)
    dt1 = dt[:, 0]                                           # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * A)                                    # (B,nh)
    h = state["h"] * dA[:, :, None, None] + jnp.einsum(
        "bs,bnp,bn->bnsp", Bc.astype(jnp.float32), xh, dt1
    )
    y = jnp.einsum("bs,bnsp->bnp", Cc.astype(jnp.float32), h)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_state = {"h": h, "conv": win[:, 1:]}
    return ctx.shard(out, "batch", None, None), new_state
