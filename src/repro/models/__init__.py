from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]
