"""Shared neural-net primitives: norms, RoPE, initializers, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_dim: Optional[int] = None, dtype=jnp.bfloat16):
    in_dim = in_dim if in_dim is not None else shape[0]
    scale = (1.0 / max(in_dim, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    angles = angles[..., None, :]                                # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL.  logits: (B, S, V) possibly vocab-sharded; labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - label_logit)
