"""Modality frontend STUBS (the one permitted carve-out).

``[audio]`` and ``[vlm]`` architectures specify only the transformer
backbone; the mel-spectrogram/EnCodec conv stack and the ViT/SigLIP vision
encoder are not implemented.  Instead, ``frontend_embeddings`` produces
precomputed frame/patch embeddings of the correct shape — deterministic
pseudo-features so tests are reproducible — and ``input_specs`` (launch/
dryrun) advertises the matching ShapeDtypeStruct.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embeddings(
    cfg: ModelConfig, batch: int, key: Optional[jax.Array] = None
) -> Optional[jax.Array]:
    """(B, frontend_tokens, d_model) stand-in features, or None."""
    if not cfg.frontend:
        return None
    if key is None:
        key = jax.random.PRNGKey(hash(cfg.frontend) % (2**31))
    emb = jax.random.normal(
        key, (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
    )
    return (emb * 0.02).astype(jnp.dtype(cfg.dtype))


def frontend_spec(cfg: ModelConfig, batch: int):
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
    )
