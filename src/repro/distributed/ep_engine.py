"""Expert-parallel MoE decode stage: pipelined all-to-all over the mesh.

The single-device engine runs the MoE stage as ONE grouped-dispatch launch
(``core.engine._grouped_expert_math``): norm2 -> route -> capacity-bucketed
``(E, C, D)`` gather -> grouped FFN -> gate-weighted scatter-add.  This
module is the mesh realization of the SAME stage for an engine whose
``ShardCtx`` carries a ``model`` axis:

* ``moe_dispatch='a2a'`` (``_ep_a2a_expert_module``) — tokens are sharded
  over the model axis; each rank routes its T/n tokens, ships every routed
  copy once to the rank owning its expert (``jax.lax.all_to_all``), runs the
  LOCAL ``(E/n, C_loc, D)`` grouped FFN, and a second all-to-all returns the
  outputs home where they are gate-weighted and scatter-added in the exact
  per-copy order of the single-device path.  The accumulated batch is split
  into ``chunks`` pipeline chunks with NO data dependence between them, so
  chunk *k+1*'s all-to-all can overlap chunk *k*'s expert FFN (EPS-MoE);
  ``serial=True`` threads an ``optimization_barrier`` between chunks to
  forbid exactly that overlap (the benchmark baseline — barriers are
  value-identity, so serial and pipelined outputs are bitwise equal).

  When capacity admits every routed token, every copy's FFN row, gate
  product and per-token add order match ``grouped_dispatch`` exactly, so
  the stage is bit-identical to the single-device grouped path.  Under
  capacity pressure the DROP SETS differ (slots are assigned per chunk at
  the expert owner, not over the full flat batch) — same contract class,
  different victims.

* ``moe_dispatch='psum'`` (``_ep_psum_expert_module``) — tokens replicated;
  every rank computes the single-device routing + full-batch arrival slots
  (drop decisions identical to single-device), runs only its LOCAL experts'
  share of the ``(E, C, D)`` buffer, and the partial outputs are summed
  with a ``psum``.  The cross-rank sum reassociates each token's k-copy
  addition, so this path is allclose- (not bit-) identical.

Collectives in this package live inside ``register_jit``-registered modules
only — rule MG107 in ``repro.analysis.lint`` enforces it.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.registry import register_jit
from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.layers import rms_norm
from repro.sharding.specs import ShardCtx, shard_map


# ---------------------------------------------------------------------------
# Static helpers (no device code)
# ---------------------------------------------------------------------------
def pipeline_chunks(t_local: int, requested: int) -> int:
    """Largest chunk count <= ``requested`` that divides the per-rank token
    count — chunked dispatch needs equal static chunk shapes."""
    c = max(1, min(int(requested), max(1, t_local)))
    while t_local % c:
        c -= 1
    return c


def a2a_bytes_per_stage(cfg: ModelConfig, T: int, n_model: int,
                        itemsize: int = 4) -> int:
    """Interconnect bytes one a2a MoE stage moves for a T-token batch:
    every routed copy crosses twice (dispatch + return) at D activation
    bytes plus one int32 metadata lane on dispatch.  Independent of the
    pipeline chunk count — chunking re-times the traffic, not its volume.
    Counts full buffer bytes (including each rank's self-share) so the
    number is comparable across mesh shapes."""
    if n_model <= 1:
        return 0
    copies = T * cfg.experts_per_token
    return copies * n_model * (2 * cfg.d_model * itemsize + 4)


def validate_ep_shard(cfg: ModelConfig, sctx: ShardCtx) -> int:
    """The mesh-engine construction contract; returns the model-axis size.

    Raises ``ValueError`` for combos the collective decode stage does not
    support — the ``ShardCtx.moe_dispatch`` threading bugfix makes these
    reachable, so they must fail loudly at construction, not mid-decode."""
    if sctx is None:
        return 1                     # no mesh: the single-device contract
    if sctx.mesh is None or sctx.model_axis is None:
        raise ValueError(
            "expert-parallel engine needs a ShardCtx with a mesh and a "
            "model_axis; for single-device serving pass sctx=None"
        )
    n = sctx.model_size
    if sctx.moe_dispatch not in ("a2a", "psum"):
        raise ValueError(
            f"moe_dispatch={sctx.moe_dispatch!r} is not a collective "
            "decode path: 'grouped' is the single-device capacity path "
            "(pass sctx=None); use 'a2a' or 'psum' on a mesh"
        )
    if cfg.num_experts % n:
        raise ValueError(
            f"num_experts={cfg.num_experts} is not divisible by the model "
            f"axis size {n}: expert-parallel dispatch shards whole expert "
            "stacks only"
        )
    return n


# ---------------------------------------------------------------------------
# a2a path: token-sharded, capacity-bucketed, pipeline-chunked
# ---------------------------------------------------------------------------
@register_jit("distributed.ep_a2a_expert")
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "axis", "chunks", "capacity", "serial"),
)
def _ep_a2a_expert_module(cfg, mesh, axis, chunks, capacity, serial,
                          norm2_w, router_w, wg, wu, wd, x):
    """The whole mesh MoE stage in one launch; returns ``(y, kept, dropped,
    load)`` with the same meaning as ``engine._grouped_expert_math``.

    ``x`` is the (T, D) accumulated decode batch with T divisible by the
    model-axis size times nothing — T % n == 0 is the caller's contract
    (the engine falls back to the single-device stage otherwise).  Each
    rank owns T/n tokens and E/n experts; ``capacity`` is the per-expert
    local buffer depth (the plan's b_e, shared with the single-device
    path)."""
    n = mesh.shape[axis]
    E = cfg.num_experts
    e_loc = E // n
    k = cfg.experts_per_token
    T, D = x.shape

    def body(xl, norm2_w, router_w, wg, wu, wd):
        T_r = xl.shape[0]
        # identical per-token math to the single-device stage: rms_norm and
        # routing are row-wise, so sharding the batch never changes a row
        h = rms_norm(xl, norm2_w, cfg.norm_eps)
        gates, idx, _ = moe_mod.route(cfg, router_w, h)
        t_c = T_r // chunks
        ys, kepts = [], []
        load = jnp.zeros((E,), jnp.int32)
        prev = None
        for c in range(chunks):
            hc = h[c * t_c:(c + 1) * t_c]
            gc = gates[c * t_c:(c + 1) * t_c].reshape(-1)      # (t_c*k,)
            ic = idx[c * t_c:(c + 1) * t_c].reshape(-1)
            if serial and prev is not None:
                # benchmark baseline: tie chunk c's inputs to chunk c-1's
                # output so the compiler cannot overlap their collectives.
                # optimization_barrier is value-identity — serial output
                # stays bitwise equal to the pipelined one.
                hc, _ = lax.optimization_barrier((hc, prev))
            tok = jnp.arange(t_c * k) // k
            dst = ic // e_loc                                  # owner rank
            # dispatch a2a: one page per destination rank, sized so the
            # send stage never drops (capacity acts at the expert owner)
            cap_s = t_c * k
            slot = moe_mod._arrival_slots(dst, n)
            send = jnp.zeros((n, cap_s, D), hc.dtype)
            send = send.at[dst, slot].add(hc[tok])
            meta = jnp.zeros((n, cap_s), jnp.int32)
            meta = meta.at[dst, slot].add(ic % e_loc + 1)      # 0 = empty
            recv = lax.all_to_all(send, axis, 0, 0, tiled=True)
            meta_r = lax.all_to_all(meta, axis, 0, 0, tiled=True)
            # local expert bucketing under the shared capacity b_e: the
            # owner sees every rank's copies for this chunk
            hr = recv.reshape(-1, D)                           # (n*cap_s, D)
            le = meta_r.reshape(-1)
            valid = le > 0
            le0 = jnp.maximum(le - 1, 0)
            slot2 = moe_mod._arrival_slots(le0, e_loc, mask=valid)
            cap_l = max(1, min(capacity, n * cap_s))
            keep = valid & (slot2 < cap_l)
            slot2_c = jnp.minimum(slot2, cap_l - 1)
            buf = jnp.zeros((e_loc, cap_l, D), hr.dtype)
            buf = buf.at[le0, slot2_c].add(
                hr * keep[:, None].astype(hr.dtype)
            )
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.grouped_expert_ffn(buf, wg, wu, wd)
            back = out[le0, slot2_c] * keep[:, None].astype(out.dtype)
            # return a2a + combine at home: same per-copy gate product and
            # flat (t, k) scatter-add order as grouped_dispatch
            ret = lax.all_to_all(
                back.reshape(n, cap_s, D), axis, 0, 0, tiled=True
            )
            got = ret[dst, slot] * gc[:, None].astype(ret.dtype)
            y_c = jnp.zeros((t_c, D), hc.dtype).at[tok].add(
                got.astype(hc.dtype)
            )
            prev = y_c
            ys.append(y_c)
            kepts.append(jnp.sum(keep.astype(jnp.int32)))
            load = load + jnp.zeros((E,), jnp.int32).at[ic].add(1)
        y = jnp.concatenate(ys, axis=0) if len(ys) > 1 else ys[0]
        # each copy is counted once at its expert owner; the psums fold the
        # per-rank partials into the single-device counter semantics
        kept = lax.psum(sum(kepts), axis)
        load = lax.psum(load, axis)
        dropped = jnp.int32(T * k) - kept
        return y, kept, dropped, load

    x_spec = P(axis, None)
    rep = P()
    e_spec = P(axis, None, None)
    y, kept, dropped, load = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, rep, rep, e_spec, e_spec, e_spec),
        out_specs=(x_spec, rep, rep, rep),
        check_vma=False,
    )(x, norm2_w, router_w, wg, wu, wd)
    return y.astype(x.dtype), kept, dropped, load


# ---------------------------------------------------------------------------
# psum path: token-replicated, single-device slotting, partial-sum combine
# ---------------------------------------------------------------------------
@register_jit("distributed.ep_psum_expert")
@functools.partial(
    jax.jit, static_argnames=("cfg", "mesh", "axis", "capacity"),
)
def _ep_psum_expert_module(cfg, mesh, axis, capacity,
                           norm2_w, router_w, wg, wu, wd, x):
    """Replicated-token expert parallelism: full-batch routing and the
    single-device arrival-slot assignment on every rank (drop decisions
    are EXACTLY the single-device ones), each rank computes only its local
    experts' share, partial outputs ``psum`` together.  The cross-rank sum
    reassociates each token's k-copy addition — allclose, not bitwise."""
    n = mesh.shape[axis]
    E = cfg.num_experts
    e_loc = E // n
    k = cfg.experts_per_token
    T, D = x.shape

    def body(xf, norm2_w, router_w, wg, wu, wd):
        r = lax.axis_index(axis)
        h = rms_norm(xf, norm2_w, cfg.norm_eps)
        gates, idx, _ = moe_mod.route(cfg, router_w, h)
        fi = idx.reshape(-1)                                   # (T*k,)
        fg = gates.reshape(-1)
        tok = jnp.arange(T * k) // k
        # single-device slotting over the FULL expert axis: capacity and
        # keep/drop per copy match grouped_dispatch exactly
        slot = moe_mod._arrival_slots(fi, E)
        keep = slot < capacity
        slot_c = jnp.minimum(slot, capacity - 1)
        mine = (fi // e_loc) == r
        fill = keep & mine
        buf = jnp.zeros((e_loc, capacity, D), h.dtype)
        buf = buf.at[fi % e_loc, slot_c].add(
            h[tok] * fill[:, None].astype(h.dtype)
        )
        from repro.kernels import ops as kernel_ops

        out = kernel_ops.grouped_expert_ffn(buf, wg, wu, wd)
        back = out[fi % e_loc, slot_c]
        back = back * (fill[:, None] * fg[:, None]).astype(back.dtype)
        y_r = jnp.zeros((T, D), h.dtype).at[tok].add(back.astype(h.dtype))
        y = lax.psum(y_r, axis)
        kept = lax.psum(jnp.sum(fill.astype(jnp.int32)), axis)
        load = jnp.zeros((E,), jnp.int32).at[fi].add(1)  # replicated math
        return y, kept, jnp.int32(T * k) - kept, load

    rep = P()
    e_spec = P(axis, None, None)
    y, kept, dropped, load = shard_map(
        body,
        mesh=mesh,
        in_specs=(rep, rep, rep, e_spec, e_spec, e_spec),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,
    )(x, norm2_w, router_w, wg, wu, wd)
    return y.astype(x.dtype), kept, dropped, load


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------
class ExpertParallelEngine:
    """Convenience facade: ``ExpertParallelEngine(cfg, params, plan, sctx,
    ...)`` IS a ``ModuleBatchingEngine`` whose MoE stage runs the collective
    dispatch.  Kept as a named entry point for discoverability — the same
    engine is reachable by passing ``sctx=`` to ``ModuleBatchingEngine``
    (or ``ServeConfig(sctx=...)`` for serving)."""

    def __new__(cls, cfg, params, plan, sctx: ShardCtx, *,
                ep_chunks: int = 1, ep_serial: bool = False, **kwargs):
        from repro.core.engine import ModuleBatchingEngine

        if sctx is None or sctx.mesh is None or sctx.model_axis is None:
            raise ValueError(
                "ExpertParallelEngine needs a ShardCtx with a mesh and "
                "model_axis; use ModuleBatchingEngine for single-device"
            )
        return ModuleBatchingEngine(
            cfg, params, plan, sctx=sctx, ep_chunks=ep_chunks,
            ep_serial=ep_serial, **kwargs,
        )


# ---------------------------------------------------------------------------
# Engine-facing stage driver
# ---------------------------------------------------------------------------
def _mesh_placed(engine, li: int, p) -> Tuple:
    """The layer's MoE params placed for the mesh launch, cached per layer:
    expert stacks sharded over the model axis, norm2/router replicated.
    Explicit ``device_put`` — a planned, once-per-layer d2d placement, so
    repeated launches move no bytes and trip no transfer guard."""
    cache = engine._ep_params
    ent = cache.get(li)
    moe = p["moe"]
    key = id(moe["experts_w_gate"])
    if ent is not None and ent[0] == key:
        return ent[1]
    sctx = engine.sctx
    rep = NamedSharding(sctx.mesh, P())
    esh = NamedSharding(sctx.mesh, P(sctx.model_axis, None, None))
    placed = (
        jax.device_put(p["norm2"], rep),      # lint: allow[MG105] once-per-layer mesh placement, cached — not streamed htod traffic
        jax.device_put(moe["router"], rep),   # lint: allow[MG105] once-per-layer mesh placement, cached
        jax.device_put(moe["experts_w_gate"], esh),  # lint: allow[MG105] once-per-layer mesh placement, cached
        jax.device_put(moe["experts_w_up"], esh),    # lint: allow[MG105] once-per-layer mesh placement, cached
        jax.device_put(moe["experts_w_down"], esh),  # lint: allow[MG105] once-per-layer mesh placement, cached
    )
    cache[li] = (key, placed)
    return placed


def ep_expert_stage(engine, li: int, p, x):
    """Run one MoE layer's collective stage for a mesh engine; returns
    ``(y, kept, dropped, load, a2a_bytes)``.

    Path selection (the ROADMAP mesh contract): ``a2a`` needs the batch
    divisible by the model-axis size — when it is not (odd live batch), the
    stage falls back to the SINGLE-DEVICE grouped launch, which is
    bit-identical anyway, so the fallback is invisible except in the a2a
    byte accounting.  ``psum`` replicates tokens and has no divisibility
    constraint."""
    from repro.analysis import runtime as sanitizer
    from repro.core import engine as engine_mod

    sctx = engine.sctx
    n = sctx.model_size
    T = x.shape[0]
    cap = engine._expert_capacity(T)
    home = x.sharding
    out = None
    if n > 1 and sctx.moe_dispatch in ("a2a", "psum"):
        norm2_w, router_w, wg, wu, wd = _mesh_placed(engine, li, p)
        if sctx.moe_dispatch == "a2a" and T % n == 0:
            # the engine's buffers are single-device committed arrays; the
            # mesh launch needs its batch sharded over the model axis and
            # hands back mesh-committed outputs — both hops are explicit,
            # planned d2d placements, tagged for the sanitizer report
            with sanitizer.allowed("ep-a2a-batch"):
                x_m = jax.device_put(      # lint: allow[MG105] planned per-launch d2d batch placement onto the mesh, tagged ep-a2a-batch
                    x, NamedSharding(sctx.mesh, P(sctx.model_axis, None))
                )
            chunks = pipeline_chunks(T // n, engine.ep_chunks)
            out = _ep_a2a_expert_module(
                engine.cfg, sctx.mesh, sctx.model_axis, chunks, cap,
                engine.ep_serial, norm2_w, router_w, wg, wu, wd, x_m,
            )
            nbytes = a2a_bytes_per_stage(
                engine.cfg, T, n, itemsize=x.dtype.itemsize
            )
        elif sctx.moe_dispatch == "psum":
            with sanitizer.allowed("ep-a2a-batch"):
                x_m = jax.device_put(      # lint: allow[MG105] planned per-launch d2d batch replication onto the mesh, tagged ep-a2a-batch
                    x, NamedSharding(sctx.mesh, P())
                )
            out = _ep_psum_expert_module(
                engine.cfg, sctx.mesh, sctx.model_axis, cap,
                norm2_w, router_w, wg, wu, wd, x_m,
            )
            nbytes = 0
    if out is None:
        # n == 1 mesh or indivisible a2a batch: the single-device grouped
        # stage IS the reference this path must match — run it directly
        y, kept, dropped, load = engine_mod._grouped_expert_module(
            engine.cfg, p, x, cap
        )
        return y, kept, dropped, load, 0
    with sanitizer.allowed("ep-a2a-combine"):
        y = jax.device_put(out[0], home)   # lint: allow[MG105] planned d2d return of the mesh stage's output to the engine's home device, tagged ep-a2a-combine
        dev = next(iter(home.device_set))
        kept, dropped, load = jax.device_put(out[1:], dev)  # lint: allow[MG105] planned d2d return of mesh-side counters, tagged ep-a2a-combine
    return y, kept, dropped, load, nbytes
