"""Data-parallel replica serving: one arrival queue over N ``Server``s.

``ReplicaServer`` fans submitted requests across independent ``Server``
replicas — each replica owns its engine, KV cache and virtual clock (the
data-parallel axis of a ``--mesh dp,ep`` deployment; each replica's engine
may itself be expert-parallel via ``ServeConfig.sctx``).  The prefix cache
is SHARED across replicas (one ``PrefixStore`` of host page rows, so a
prompt prefilled on replica 0 is a prefix hit on replica 1) while KV stays
per-replica.

Routing is pluggable: ``'round-robin'``, ``'least-loaded'`` (fewest
outstanding decode tokens, the default), or any callable
``(servers, request) -> replica index``.

The merged report sums work counters across replicas and takes the
parallel wall-clock (max of the per-replica phase times) — replicas run
concurrently in a real deployment, sequentially interleaved here on one
host, so per-replica reports carry the honest individual timings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Union

import numpy as np

from repro import faults
from repro.serving.server import (
    Request,
    RequestHandle,
    ServeConfig,
    ServeReport,
    Server,
    StreamConfig,
)

ROUTING_POLICIES = ("round-robin", "least-loaded")


@dataclass
class ReplicaReport:
    """``merged`` carries the fleet view; ``per_replica`` the honest
    individual reports (their own clocks and counters)."""

    merged: ServeReport
    per_replica: List[ServeReport]


class ReplicaServer:
    """Facade matching the ``Server`` submit/run surface over N replicas."""

    def __init__(
        self,
        cfg,
        params,
        n_replicas: int,
        plan=None,
        serve: ServeConfig = ServeConfig(),
        stream: StreamConfig = StreamConfig(),
        policy: Union[str, Callable] = "least-loaded",
    ) -> None:
        assert n_replicas >= 1, n_replicas
        if isinstance(policy, str) and policy not in ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; pick one of "
                f"{ROUTING_POLICIES} or pass a callable"
            )
        self.policy = policy
        # ONE resolved fault plan shared by the fleet and every replica
        # (one ledger; the kill schedule is consulted on the fleet's step
        # clock, the stream/page/preempt seams on each replica's)
        self._faults = faults.resolve(serve.faults)
        if self._faults is not None:
            serve = replace(serve, faults=self._faults)
        self.servers = [
            Server(cfg, params, plan, serve, stream)
            for _ in range(n_replicas)
        ]
        # shared prefix keys, per-replica KV: every replica consults one
        # PrefixStore (host page rows), so replica 1 hits what replica 0
        # prefilled; the device page pools stay replica-local
        if self.servers[0]._prefix is not None:
            for s in self.servers[1:]:
                s._prefix = self.servers[0]._prefix
        self._rr = 0
        self._routes: List[tuple] = []    # global index -> (replica, local)
        # failover state: dead replicas never step again; their unfinished
        # requests are resubmitted from scratch onto survivors (the
        # sampling determinism contract makes the regenerated streams
        # token-identical) and the routes remapped
        self._dead: set = set()
        self._steps = 0                   # fleet step clock (kill schedule)
        self.failovers = 0
        self.requeued = 0

    # -- routing -----------------------------------------------------------
    def _alive(self) -> List[int]:
        return [i for i in range(len(self.servers)) if i not in self._dead]

    def _outstanding(self, server: Server) -> int:
        """Decode tokens still owed by a replica's unfinished requests —
        the least-loaded signal."""
        return sum(h.decode_len for h in server._handles if not h.finished)

    def _pick(self, request: Request) -> int:
        alive = self._alive()
        if callable(self.policy):
            i = int(self.policy(self.servers, request)) % len(self.servers)
            if i in self._dead:
                i = alive[i % len(alive)]
            return i
        if self.policy == "round-robin":
            i = alive[self._rr % len(alive)]
            self._rr += 1
            return i
        loads = [self._outstanding(self.servers[i]) for i in alive]
        return alive[int(np.argmin(loads))]

    # -- Server-shaped surface --------------------------------------------
    def submit(self, request: Request,
               on_token=None) -> RequestHandle:
        i = self._pick(request)
        h = self.servers[i].submit(request, on_token)
        self._routes.append((i, h.index))
        return h

    def has_work(self) -> bool:
        return any(self.servers[i].has_work() for i in self._alive())

    def step(self) -> bool:
        """One interleaved tick: every live replica with work steps once.

        Failure detection: an injected kill (the fault plan's
        ``kill=R@N`` schedule, on this fleet step clock) or a replica
        whose step escapes with a ``faults.FaultError`` (recovery
        exhausted — e.g. ``StreamTimeoutError``) declares the replica
        dead; its unfinished requests fail over to survivors.  Any other
        exception type propagates — bugs abort loudly, they are not
        absorbed by failover."""
        self._steps += 1
        fp = self._faults if self._faults is not None else faults.current()
        for i in self._alive():
            s = self.servers[i]
            if fp is not None and fp.kill_due(i, self._steps):
                self._kill(i)
                continue
            if s.has_work():
                try:
                    s.step()
                except faults.FaultError:
                    self._kill(i)
        return self.has_work()

    def _kill(self, i: int) -> None:
        """Declare replica ``i`` dead and fail over: its unfinished
        requests (queued, running, or preempted — their KV is lost with
        the replica) are resubmitted from scratch onto survivors, and the
        global routes remapped so the merged report carries the
        survivor's token-identical regenerated results.  Requests the
        replica already finished keep their results.  Streaming callbacks
        on failed-over requests re-fire from the first token
        (at-least-once delivery)."""
        self._dead.add(i)
        alive = self._alive()
        if not alive:
            raise faults.FaultError(
                f"replica {i} died with no survivors to fail over to"
            )
        self.failovers += 1
        faults_local = self._faults
        if faults_local is not None:
            faults_local.note("failover")
        back = {(ri, local): g for g, (ri, local) in enumerate(self._routes)}
        for h in self.servers[i]._handles:
            if h.finished:
                continue
            j = alive[self._rr % len(alive)]
            self._rr += 1
            nh = self.servers[j].submit(
                Request(h.prompt, h.decode_len, arrival_s=h.arrival_s,
                        sampling=h.sampling),
                on_token=h.on_token,
            )
            self._routes[back[(i, h.index)]] = (j, nh.index)
            self.requeued += 1
            if faults_local is not None:
                faults_local.note("failover-requeue")
        # the dead replica never steps again — drop its queue/checkpoints
        # so fleet-level idle checks don't see phantom work
        self.servers[i]._pending.clear()
        self.servers[i]._ckpts.clear()

    def _wait_for_arrival(self) -> None:
        waits = [
            s.next_arrival_s - s._now()
            for s in (self.servers[i] for i in self._alive())
            if s._pending and not s._any_live()
        ]
        if waits:
            dt = min(waits)
            if dt > 0:
                time.sleep(min(dt, 0.05))

    def run(self, until_idle: bool = True) -> ReplicaReport:
        while self.step():
            alive = [self.servers[i] for i in self._alive()]
            if (not any(s._any_live() for s in alive)
                    and any(s._pending for s in alive)):
                if not until_idle:
                    break
                self._wait_for_arrival()
        return self.finalize()

    def finalize(self) -> ReplicaReport:
        reports = [s.finalize() for s in self.servers]
        return ReplicaReport(self._merge(reports), reports)

    # -- merging -----------------------------------------------------------
    def _merge(self, reports: List[ServeReport]) -> ServeReport:
        m = ServeReport(scheduler=reports[0].scheduler)
        # parallel wall-clock: replicas run concurrently in deployment, so
        # the fleet phase time is the slowest replica's, while work
        # counters (tokens, bytes, slot-steps) sum
        m.prefill_s = max(r.prefill_s for r in reports)
        m.decode_s = max(r.decode_s for r in reports)
        for r in reports:
            m.results.extend(r.results)
            m.decode_slot_steps += r.decode_slot_steps
            m.wasted_slot_steps += r.wasted_slot_steps
            m.weight_htod_bytes += r.weight_htod_bytes
            m.prefetch_wait_s += r.prefetch_wait_s
            m.admission_deferrals += r.admission_deferrals
            m.kv_htod_bytes += r.kv_htod_bytes
            m.kv_dtoh_bytes += r.kv_dtoh_bytes
            m.prefill_tokens += r.prefill_tokens
            m._expert_dropped += r._expert_dropped
            m.expert_pred_hits += r.expert_pred_hits
            m.expert_pred_misses += r.expert_pred_misses
            m.expert_lru_hits += r.expert_lru_hits
            m.capacity_replans += r.capacity_replans
            m.a2a_bytes += r.a2a_bytes
            m.collective_dispatches += r.collective_dispatches
            m.transfer_retries += r.transfer_retries
            m.transfer_timeouts += r.transfer_timeouts
            m.preemptions += r.preemptions
            m.resumes += r.resumes
            m.degrade_deferrals += r.degrade_deferrals
            m.page_demotions += r.page_demotions
            m.chunk_shrinks += r.chunk_shrinks
            if r.expert_load is not None:
                if m.expert_load is None:
                    m.expert_load = r.expert_load.copy()
                    m.expert_dropped_by_layer = (
                        r.expert_dropped_by_layer.copy()
                    )
                else:
                    m.expert_load += r.expert_load
                    m.expert_dropped_by_layer += r.expert_dropped_by_layer
        # one shared PrefixStore means each replica reported the SAME
        # store counters — take them once, don't sum
        shared = (len(self.servers) > 1
                  and self.servers[0]._prefix is not None
                  and all(s._prefix is self.servers[0]._prefix
                          for s in self.servers))
        if shared:
            m.prefix_hits = reports[0].prefix_hits
            m.prefix_misses = reports[0].prefix_misses
        else:
            m.prefix_hits = sum(r.prefix_hits for r in reports)
            m.prefix_misses = sum(r.prefix_misses for r in reports)
        # request results re-indexed to global submission order
        by_replica = [
            {rr.index: rr for rr in r.request_results} for r in reports
        ]
        for g, (i, local) in enumerate(self._routes):
            rr = by_replica[i].get(local)
            if rr is not None:
                m.request_results.append(replace(rr, index=g))
        m.request_results.sort(key=lambda r: r.index)
        # fleet-level failover accounting (replicas can't see it)
        m.failovers = self.failovers
        m.requeued_requests = self.requeued
        return m
