"""Data-parallel replica serving: one arrival queue over N ``Server``s.

``ReplicaServer`` fans submitted requests across independent ``Server``
replicas — each replica owns its engine, KV cache and virtual clock (the
data-parallel axis of a ``--mesh dp,ep`` deployment; each replica's engine
may itself be expert-parallel via ``ServeConfig.sctx``).  The prefix cache
is SHARED across replicas (one ``PrefixStore`` of host page rows, so a
prompt prefilled on replica 0 is a prefix hit on replica 1) while KV stays
per-replica.

Routing is pluggable: ``'round-robin'``, ``'least-loaded'`` (fewest
outstanding decode tokens, the default), or any callable
``(servers, request) -> replica index``.

The merged report sums work counters across replicas and takes the
parallel wall-clock (max of the per-replica phase times) — replicas run
concurrently in a real deployment, sequentially interleaved here on one
host, so per-replica reports carry the honest individual timings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Union

import numpy as np

from repro.serving.server import (
    Request,
    RequestHandle,
    ServeConfig,
    ServeReport,
    Server,
    StreamConfig,
)

ROUTING_POLICIES = ("round-robin", "least-loaded")


@dataclass
class ReplicaReport:
    """``merged`` carries the fleet view; ``per_replica`` the honest
    individual reports (their own clocks and counters)."""

    merged: ServeReport
    per_replica: List[ServeReport]


class ReplicaServer:
    """Facade matching the ``Server`` submit/run surface over N replicas."""

    def __init__(
        self,
        cfg,
        params,
        n_replicas: int,
        plan=None,
        serve: ServeConfig = ServeConfig(),
        stream: StreamConfig = StreamConfig(),
        policy: Union[str, Callable] = "least-loaded",
    ) -> None:
        assert n_replicas >= 1, n_replicas
        if isinstance(policy, str) and policy not in ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; pick one of "
                f"{ROUTING_POLICIES} or pass a callable"
            )
        self.policy = policy
        self.servers = [
            Server(cfg, params, plan, serve, stream)
            for _ in range(n_replicas)
        ]
        # shared prefix keys, per-replica KV: every replica consults one
        # PrefixStore (host page rows), so replica 1 hits what replica 0
        # prefilled; the device page pools stay replica-local
        if self.servers[0]._prefix is not None:
            for s in self.servers[1:]:
                s._prefix = self.servers[0]._prefix
        self._rr = 0
        self._routes: List[tuple] = []    # global index -> (replica, local)

    # -- routing -----------------------------------------------------------
    def _outstanding(self, server: Server) -> int:
        """Decode tokens still owed by a replica's unfinished requests —
        the least-loaded signal."""
        return sum(h.decode_len for h in server._handles if not h.finished)

    def _pick(self, request: Request) -> int:
        if callable(self.policy):
            return int(self.policy(self.servers, request)) % len(self.servers)
        if self.policy == "round-robin":
            i = self._rr % len(self.servers)
            self._rr += 1
            return i
        loads = [self._outstanding(s) for s in self.servers]
        return int(np.argmin(loads))

    # -- Server-shaped surface --------------------------------------------
    def submit(self, request: Request,
               on_token=None) -> RequestHandle:
        i = self._pick(request)
        h = self.servers[i].submit(request, on_token)
        self._routes.append((i, h.index))
        return h

    def has_work(self) -> bool:
        return any(s.has_work() for s in self.servers)

    def step(self) -> bool:
        """One interleaved tick: every replica with work steps once."""
        for s in self.servers:
            if s.has_work():
                s.step()
        return self.has_work()

    def _wait_for_arrival(self) -> None:
        waits = [
            s.next_arrival_s - s._now()
            for s in self.servers
            if s._pending and not s._any_live()
        ]
        if waits:
            dt = min(waits)
            if dt > 0:
                time.sleep(min(dt, 0.05))

    def run(self, until_idle: bool = True) -> ReplicaReport:
        while self.step():
            if (not any(s._any_live() for s in self.servers)
                    and any(s._pending for s in self.servers)):
                if not until_idle:
                    break
                self._wait_for_arrival()
        return self.finalize()

    def finalize(self) -> ReplicaReport:
        reports = [s.finalize() for s in self.servers]
        return ReplicaReport(self._merge(reports), reports)

    # -- merging -----------------------------------------------------------
    def _merge(self, reports: List[ServeReport]) -> ServeReport:
        m = ServeReport(scheduler=reports[0].scheduler)
        # parallel wall-clock: replicas run concurrently in deployment, so
        # the fleet phase time is the slowest replica's, while work
        # counters (tokens, bytes, slot-steps) sum
        m.prefill_s = max(r.prefill_s for r in reports)
        m.decode_s = max(r.decode_s for r in reports)
        for r in reports:
            m.results.extend(r.results)
            m.decode_slot_steps += r.decode_slot_steps
            m.wasted_slot_steps += r.wasted_slot_steps
            m.weight_htod_bytes += r.weight_htod_bytes
            m.prefetch_wait_s += r.prefetch_wait_s
            m.admission_deferrals += r.admission_deferrals
            m.kv_htod_bytes += r.kv_htod_bytes
            m.kv_dtoh_bytes += r.kv_dtoh_bytes
            m.prefill_tokens += r.prefill_tokens
            m._expert_dropped += r._expert_dropped
            m.expert_pred_hits += r.expert_pred_hits
            m.expert_pred_misses += r.expert_pred_misses
            m.expert_lru_hits += r.expert_lru_hits
            m.capacity_replans += r.capacity_replans
            m.a2a_bytes += r.a2a_bytes
            m.collective_dispatches += r.collective_dispatches
            if r.expert_load is not None:
                if m.expert_load is None:
                    m.expert_load = r.expert_load.copy()
                    m.expert_dropped_by_layer = (
                        r.expert_dropped_by_layer.copy()
                    )
                else:
                    m.expert_load += r.expert_load
                    m.expert_dropped_by_layer += r.expert_dropped_by_layer
        # one shared PrefixStore means each replica reported the SAME
        # store counters — take them once, don't sum
        shared = (len(self.servers) > 1
                  and self.servers[0]._prefix is not None
                  and all(s._prefix is self.servers[0]._prefix
                          for s in self.servers))
        if shared:
            m.prefix_hits = reports[0].prefix_hits
            m.prefix_misses = reports[0].prefix_misses
        else:
            m.prefix_hits = sum(r.prefix_hits for r in reports)
            m.prefix_misses = sum(r.prefix_misses for r in reports)
        # request results re-indexed to global submission order
        by_replica = [
            {rr.index: rr for rr in r.request_results} for r in reports
        ]
        for g, (i, local) in enumerate(self._routes):
            rr = by_replica[i].get(local)
            if rr is not None:
                m.request_results.append(replace(rr, index=g))
        m.request_results.sort(key=lambda r: r.index)
        return m
