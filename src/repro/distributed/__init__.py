"""Distributed serving: expert-parallel mesh engine + data-parallel replicas.

Three layers (see README.md in this package):

* ``ep_engine`` — the collective MoE decode stage (pipelined all-to-all /
  psum) a ``ModuleBatchingEngine`` built with a mesh ``ShardCtx`` selects,
  plus the ``ExpertParallelEngine`` convenience facade.
* ``replicas`` — ``ReplicaServer``: one arrival queue fanned across N
  ``Server`` replicas with a pluggable routing policy and a merged report.
"""
from repro.distributed.ep_engine import (
    ExpertParallelEngine,
    a2a_bytes_per_stage,
    pipeline_chunks,
    validate_ep_shard,
)
from repro.distributed.replicas import ReplicaReport, ReplicaServer

__all__ = [
    "a2a_bytes_per_stage",
    "ExpertParallelEngine",
    "pipeline_chunks",
    "ReplicaReport",
    "ReplicaServer",
    "validate_ep_shard",
]
