"""Data pipeline: synthetic token streams shaped like the paper's benchmarks.

The paper evaluates on MMLU / GSM8K / ChatBot-Arena / LongBench (Table 4,
Table 8).  Offline, we reproduce their *workload shapes* (sequence counts,
prompt and decode lengths) with deterministic synthetic token data, which is
sufficient for every throughput/scheduling claim (the systems are
content-agnostic).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_sequences: int
    prompt_len: int
    decode_len: int


# Paper Table 4 workloads
DATASETS = {
    "mmlu": DatasetSpec("mmlu", 116_000, 512, 1),
    "gsm8k": DatasetSpec("gsm8k", 8_500, 512, 256),
    "chatbot-arena": DatasetSpec("chatbot-arena", 36_000, 256, 512),
    # LongBench configurations of Table 8
    "longbench-16k-8k": DatasetSpec("longbench-16k-8k", 50, 16_384, 8_192),
    "longbench-8k-16k": DatasetSpec("longbench-8k-16k", 50, 8_192, 16_384),
    "longbench-8k-4k": DatasetSpec("longbench-8k-4k", 100, 8_192, 4_096),
    "longbench-4k-2k": DatasetSpec("longbench-4k-2k", 200, 4_096, 2_048),
}


def synthetic_requests(
    spec: DatasetSpec,
    vocab_size: int,
    limit: int | None = None,
    seed: int = 0,
    prompt_lens: Sequence[int] | None = None,
    decode_lens: Sequence[int] | None = None,
    arrivals: Sequence[float] | None = None,
    sampling=None,
) -> List["Request"]:
    """Deterministic synthetic requests shaped like ``spec``.

    ``prompt_lens`` / ``decode_lens`` override the spec's uniform lengths
    with a cycled mixed-length workload (ragged prompts / in-flight decode
    lengths) — the shape the continuous scheduler exists for.

    ``arrivals`` stamps per-request ``arrival_s`` offsets (an open-loop
    online workload — see ``repro.serving.arrivals``; must cover every
    request, it is not cycled).  ``sampling`` attaches one
    ``SamplingParams`` decoding policy to every request (None = greedy).
    """
    from repro.serving.arrivals import assign
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(seed)
    n = min(spec.num_sequences, limit or spec.num_sequences)
    requests = [
        Request(
            prompt=rng.integers(
                0, vocab_size,
                prompt_lens[i % len(prompt_lens)] if prompt_lens
                else spec.prompt_len,
                dtype=np.int32,
            ),
            decode_len=(
                decode_lens[i % len(decode_lens)] if decode_lens
                else spec.decode_len
            ),
            sampling=sampling,
        )
        for i in range(n)
    ]
    if arrivals is not None:
        assign(requests, arrivals)
    return requests


def synthetic_batches(
    vocab_size: int,
    batch: int,
    seq: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Infinite stream of (tokens, labels) for language-model training."""
    rng = np.random.default_rng(seed)
    while True:
        # mildly structured stream (zipfian-ish) so the loss can decrease
        base = rng.zipf(1.5, size=(batch, seq + 1)) % vocab_size
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        yield tokens, labels
