from repro.data.datasets import DATASETS, DatasetSpec, synthetic_batches, synthetic_requests
from repro.data.tokenizer import ByteTokenizer

__all__ = [
    "DATASETS",
    "DatasetSpec",
    "synthetic_batches",
    "synthetic_requests",
    "ByteTokenizer",
]
