"""Minimal byte-level tokenizer (self-contained, offline)."""
from __future__ import annotations

from typing import List

import numpy as np


class ByteTokenizer:
    """UTF-8 bytes + BOS/EOS; vocab 256 + 2 specials."""

    BOS = 256
    EOS = 257
    vocab_size = 258

    def encode(self, text: str, add_bos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [self.BOS] + ids
        return np.asarray(ids, np.int32)

    def decode(self, ids: List[int]) -> str:
        data = bytes(i for i in ids if i < 256)
        return data.decode("utf-8", errors="replace")
