"""Jit'd public wrappers around the Pallas kernels.

Handle padding to tile multiples, GQA head repetition, and backend
selection: ``interpret=True`` (Python interpretation, bit-exact oracle
semantics) everywhere except real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import expert_gemm as _eg
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def default_use_kernel() -> bool:
    """Run the compiled Pallas kernels only on real TPU; everywhere else the
    XLA einsum fallback is both faster and bit-stable."""
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_matmul(x, w, interpret=None):
    """(E, C, D) @ (E, D, F) with automatic tile padding."""
    interpret = default_interpret() if interpret is None else interpret
    E, C, D = x.shape
    F = w.shape[-1]
    bc = min(128, C) if C % 128 else 128
    xp = _pad_to(x, 1, 128)
    xp = _pad_to(xp, 2, 256)
    wp = _pad_to(_pad_to(w, 1, 256), 2, 128)
    y = _eg.grouped_matmul(xp, wp, interpret=interpret)
    return y[:, :C, :F]


@functools.partial(jax.jit, static_argnames=("interpret",))
def expert_ffn(x, wg, wu, wd, interpret=None):
    """Fused gated expert FFN with tile padding."""
    interpret = default_interpret() if interpret is None else interpret
    E, C, D = x.shape
    xp = _pad_to(x, 1, 128)
    wgp = _pad_to(wg, 2, 128)
    wup = _pad_to(wu, 2, 128)
    wdp = _pad_to(wd, 1, 128)
    y = _eg.expert_ffn(xp, wgp, wup, wdp, interpret=interpret)
    return y[:, :C, :]


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def grouped_expert_ffn(x, wg, wu, wd, use_kernel=None):
    """Backend-dispatched grouped expert FFN over (E, C, D) capacity buffers.

    One launch covers every expert: the fused Pallas kernel on real TPU, an
    einsum-based XLA path elsewhere.  The fallback uses the same op sequence
    as a per-expert ``x @ w`` chain (bf16 intermediates), so it is
    bit-compatible with the engine's sequential-loop oracle; the Pallas
    kernel keeps an f32 VMEM accumulator and agrees to bf16 rounding
    (tests/test_kernels.py).
    """
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    if use_kernel:
        # interpret=None: compiled on TPU, interpret mode if the kernel is
        # forced on a backend Pallas cannot compile for
        return expert_ffn(x, wg, wu, wd, interpret=None)
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, pos, interpret=None):
    """(B,H,hd) x (B,S,K,hd) -> (B,H,hd), masked to slots <= pos."""
    interpret = default_interpret() if interpret is None else interpret
    S = k.shape[1]
    block_s = 256 if S % 256 == 0 else S
    return _da.decode_attention(
        q, k, v, pos, block_s=block_s, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, causal=True, interpret=None):
    """(B,S,H,hd) GQA causal attention (KV heads repeated as needed)."""
    interpret = default_interpret() if interpret is None else interpret
    H, K = q.shape[2], k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    S = q.shape[1]
    block = 256 if S % 256 == 0 else S
    return _fa.flash_attention(
        q, k, v, block_q=block, block_k=block, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, B_in, C_in, dt, A, chunk, interpret=None):
    """Chunked SSD scan; returns (y, final_state)."""
    interpret = default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan_pallas(
        x, B_in, C_in, dt, A, chunk, interpret=interpret
    )
