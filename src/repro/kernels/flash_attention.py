"""Pallas TPU causal flash-attention (prefill/train path) with block skipping.

Unlike the XLA fallback (models.attention.blocked_attention), which computes
and masks every (q-block, kv-block) pair (~2x the causal FLOPs), this kernel
skips fully-masked blocks with ``pl.when`` — the proper TPU fix for the
compute-term overcount called out in EXPERIMENTS.md §Roofline.

Layout: MHA-shaped (GQA callers repeat KV heads in ops.py).  Grid
(B, H, nq, nk), kv innermost; online softmax in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, n_k: int, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skipping: kv block strictly above the diagonal => no work
    @pl.when(j * block_k <= i * block_q + block_q - 1)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)           # (bq, hd)
        ks = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, hd)
        vs = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, ks, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (bq, bk)
        qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _store():
        o_ref[0, 0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,        # (B, S, H, hd)
    k: jax.Array,        # (B, S, H, hd)   (KV heads pre-repeated for GQA)
    v: jax.Array,
    *,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    assert k.shape == q.shape and v.shape == q.shape
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    qt = q.transpose(0, 2, 1, 3).reshape(B, H, nq, block_q, hd)
    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, n_k=nk,
            scale=hd ** -0.5,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, nq, block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, k, v)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
