"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, nh/bh, n_chunks) with the chunk dim innermost and sequential: the
recurrent state H (bh, ns, hp) lives in a VMEM scratch that persists across
chunk steps — the TPU-native form of the inter-chunk recurrence, while the
intra-chunk quadratic work feeds the MXU.  Oracle: models.ssm._chunk_math
via kernels/ref.ssd_chunk_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, y_ref, hout_ref, h_ref,
                *, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, bh, hp)
    Bc = b_ref[0, 0].astype(jnp.float32)         # (Q, ns)
    Cc = c_ref[0, 0].astype(jnp.float32)         # (Q, ns)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, bh)
    dA = da_ref[0, 0].astype(jnp.float32)        # (Q, bh)
    Q = x.shape[0]

    cum = jnp.cumsum(dA, axis=0)                 # (Q, bh)
    diff = cum[:, None, :] - cum[None, :, :]     # (i, j, bh)
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask before exp (above-diagonal diffs overflow) — matches the oracle
    L = jnp.exp(jnp.where(causal[:, :, None], diff, -1e30))
    CB = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (i, j)
    M = CB[:, :, None] * L * dt[None, :, :]      # (i, j, bh)
    y_intra = jnp.einsum("ijn,jnp->inp", M, x)
    H = h_ref[...]                               # (bh, ns, hp)
    y_inter = jnp.einsum("is,nsp->inp", Cc, H) * jnp.exp(cum)[..., None]
    w = jnp.exp(cum[-1:, :] - cum) * dt          # (Q, bh)
    S_c = jnp.einsum("jn,js,jnp->nsp", w, Bc, x)
    h_ref[...] = H * jnp.exp(cum[-1])[:, None, None] + S_c
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _store_state():
        hout_ref[0] = h_ref[...]


def ssd_scan_pallas(
    x: jax.Array,       # (B, S, nh, hp)
    B_in: jax.Array,    # (B, S, ns)
    C_in: jax.Array,    # (B, S, ns)
    dt: jax.Array,      # (B, S, nh) f32
    A: jax.Array,       # (nh,) f32 negative
    chunk: int,
    *,
    block_h: int = 0,
    interpret: bool = True,
):
    """Returns (y (B,S,nh,hp), final_state (B,nh,ns,hp) f32)."""
    Bt, S, nh, hp = x.shape
    ns = B_in.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    bh = block_h or nh
    assert nh % bh == 0
    dA = dt * A

    xr = x.reshape(Bt, nc, Q, nh, hp)
    br = B_in.reshape(Bt, nc, Q, ns)
    cr = C_in.reshape(Bt, nc, Q, ns)
    dtr = dt.reshape(Bt, nc, Q, nh)
    dar = dA.reshape(Bt, nc, Q, nh)

    grid = (Bt, nh // bh, nc)
    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, bh, hp), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, ns), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ns), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, bh), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, bh), lambda b, h, c: (b, c, 0, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, bh, hp), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, bh, ns, hp), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, nc, Q, nh, hp), x.dtype),
            jax.ShapeDtypeStruct((Bt, nh, ns, hp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, ns, hp), jnp.float32)],
        interpret=interpret,
    )(xr, br, cr, dtr, dar)
    return y.reshape(Bt, S, nh, hp), hout
