"""Pallas TPU flash-decode kernel (GQA, one new token vs. a long KV cache).

The decode attention module is the second hot module of MoE-Gen's batching
(the paper batches it at ``b_a``).  Grid (B, K, S/blk): for each (sequence,
kv-head) the kernel streams KV blocks HBM->VMEM with an online-softmax
accumulator, masking cache slots beyond the current position (scalar-
prefetched).  The grouped query heads (G = H/K) ride in the sublane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(
    pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
    block_s: int, n_s: int, scale: float,
):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    ks = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, hd)
    vs = v_ref[0, :, 0, :].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, ks, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                            # (G, bs)
    idx = s * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(idx <= pos_ref[0], scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _store():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,        # (B, H, hd)
    k: jax.Array,        # (B, S, K, hd)
    v: jax.Array,        # (B, S, K, hd)
    pos: jax.Array,      # scalar int32: attend to slots <= pos
    *,
    block_s: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, K, _ = k.shape
    G = H // K
    assert S % block_s == 0, (S, block_s)
    n_s = S // block_s
    qg = q.reshape(B, K, G, hd)
    grid = (B, K, n_s)
    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, block_s=block_s, n_s=n_s,
            scale=hd ** -0.5,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, s, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s, pos: (b, s, h, 0)),
                pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s, pos: (b, s, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s, pos: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), qg, k, v)
    return out.reshape(B, H, hd)
