"""Pallas TPU kernels for the MoE expert module.

Two kernels:

* ``grouped_matmul``  — (E, C, D) @ (E, D, F) -> (E, C, F): the generic
  grouped GEMM building block, MXU-tiled.
* ``expert_ffn``      — the fused gated FFN silu(x@wg)*(x@wu) @ wd with the
  token tile and the f32 accumulator resident in VMEM across the F-tile
  loop.  This is the TPU adaptation of MoE-Gen's insight: amortize each
  expert-weight fetch (HBM->VMEM here, host->HBM at the system level) over
  the largest possible token batch.

Both kernels are validated against kernels/ref.py in interpret mode across
shape/dtype sweeps (tests/test_kernels.py); ``kernels/ops.py`` holds the
jit'd padding wrappers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Grouped GEMM
# ---------------------------------------------------------------------------
def _grouped_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_kd: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0],
        w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == n_kd - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,          # (E, C, D)
    w: jax.Array,          # (E, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: bool = True,
) -> jax.Array:
    E, C, D = x.shape
    _, _, F = w.shape
    assert w.shape == (E, D, F)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0, (
        x.shape, w.shape, (block_c, block_f, block_d),
    )
    n_kd = D // block_d
    grid = (E, C // block_c, F // block_f, n_kd)
    return pl.pallas_call(
        functools.partial(_grouped_matmul_kernel, n_kd=n_kd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused gated expert FFN
# ---------------------------------------------------------------------------
def _expert_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f: int):
    """Grid (E, C/bc, F/bf).  x tile and acc stay resident across the F loop."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, D)
    g = jax.lax.dot_general(
        x, wg_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (bc, bf)
    u = jax.lax.dot_general(
        x, wu_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_f - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_ffn(
    x: jax.Array,          # (E, C, D)
    wg: jax.Array,         # (E, D, F)
    wu: jax.Array,         # (E, D, F)
    wd: jax.Array,         # (E, F, D)
    *,
    block_c: int = 128,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    E, C, D = x.shape
    F = wg.shape[-1]
    assert C % block_c == 0 and F % block_f == 0
    n_f = F // block_f
    grid = (E, C // block_c, n_f)
    return pl.pallas_call(
        functools.partial(_expert_ffn_kernel, n_f=n_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, D, block_f), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1, block_f, D), lambda e, i, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, D), lambda e, i, j: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, D), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
