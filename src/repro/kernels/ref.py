"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E, C, D) @ (E, D, F) -> (E, C, F), f32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
                      ).astype(x.dtype)


def expert_ffn_ref(x: jax.Array, wg, wu, wd) -> jax.Array:
    """Fused gated expert FFN: silu(x@wg) * (x@wu) @ wd."""
    g = jnp.einsum("ecd,edf->ecf", x, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, wu, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def decode_attention_ref(
    q: jax.Array,       # (B, H, D)
    k: jax.Array,       # (B, S, K, D)
    v: jax.Array,       # (B, S, K, D)
    pos: int,           # attend to slots <= pos
) -> jax.Array:
    B, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(k.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """(B, S, H, D) x (B, S, K, D) -> (B, S, H, D) full-precision attention."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.reshape(B, S, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def ssd_chunk_ref(x, B_c, C_c, dt, dA, h0):
    """Single SSD chunk oracle — mirrors models.ssm._chunk_math."""
    from repro.models.ssm import _chunk_math

    return _chunk_math(x, B_c, C_c, dt, dA, h0)
