"""Deterministic fault injection + recovery policies (see ``plan.py``)."""
from repro.faults.plan import (  # noqa: F401
    FaultError,
    FaultPlan,
    FaultSpec,
    PageAllocOOM,
    RetryPolicy,
    StreamTimeoutError,
    TransientTransferError,
    armed,
    current,
    note,
    parse_spec,
    resolve,
    shielded,
)

__all__ = [
    "FaultError", "FaultPlan", "FaultSpec", "PageAllocOOM", "RetryPolicy",
    "StreamTimeoutError", "TransientTransferError", "armed", "current",
    "note", "parse_spec", "resolve", "shielded",
]
