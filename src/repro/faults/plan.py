"""Deterministic fault injection and recovery policy for the serving stack.

The serving stack (streamed weights, paged KV, replica fan-out) operates
at the resource limit, where transient ``device_put`` failures, host
memory spikes, and dead replicas are routine rather than exceptional.
This module provides the *injection* half of the fault-tolerance
contract; the recovery policies live at the seams they protect
(``serving/weights.py``, ``serving/cache.py``, ``serving/server.py``,
``distributed/replicas.py``).

Design constraints:

* **Deterministic.** Every injection decision is a pure function of
  ``(seed, site, per-site event counter)`` hashed through
  ``hashlib.blake2b`` — never wall-clock time or Python's per-process
  salted ``hash``.  Replaying the same schedule against the same request
  stream reproduces the same faults, which is what makes the chaos
  property tests (token-identical to the fault-free run) possible.
* **Bounded.** A site never draws two *consecutive* transient failures,
  so any retry policy with ``max_retries >= 1`` is guaranteed to make
  progress — injected faults perturb the run, they never wedge it.
* **Unarmed == absent.** Every seam guards on ``faults.current() is
  None`` first; with no plan armed (no ``REPRO_FAULTS`` env, no
  ``ServeConfig.faults``) the serving path is byte-for-byte identical to
  a build without this package.

Arming mirrors the sanitizer (``repro.analysis.runtime``): an explicit
``with faults.armed(plan):`` region wins over the ambient process-wide
plan parsed from the ``REPRO_FAULTS`` env var; ``faults.shielded()``
masks the ambient plan for fault-free baselines inside a chaos-armed
process.  ``REPRO_FAULTS_REPORT=<path>`` dumps the injected/recovered
event counts as JSON at interpreter exit (a CI artifact).
"""
from __future__ import annotations

import atexit
import contextlib
import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional


# --------------------------------------------------------------------------
# errors
# --------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base class for fault-path errors.

    ``ReplicaServer`` treats a replica raising a ``FaultError`` (recovery
    exhausted) as dead and fails its requests over to survivors; any
    other exception type propagates — a bug should abort loudly, not be
    silently absorbed by failover.
    """


class TransientTransferError(FaultError):
    """A stream transfer failed transiently (retryable)."""


class StreamTimeoutError(FaultError):
    """A ``StreamWindow.acquire`` wait exceeded the watchdog deadline.

    Raised only after the one-shot recovery (abandon the dead in-flight
    entry, demand re-fetch) also fails — names the window tag and key so
    the hang is attributable.
    """


class PageAllocOOM(FaultError):
    """KV page-frame allocation found no free frame (host and device
    tiers exhausted, or an injected OOM)."""


# --------------------------------------------------------------------------
# policies & specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """The ONE retry policy shared by weight, expert-prefetch and KV-page
    stream traffic (``StreamWindow`` instances of every tag).

    ``watchdog_s=None`` keeps the historical unbounded
    ``block_until_ready`` wait on ``acquire``; a finite watchdog polls
    device-buffer readiness against a deadline instead, so a dead
    in-flight future surfaces as ``StreamTimeoutError`` rather than a
    hang.
    """

    max_retries: int = 3
    backoff_s: float = 0.001
    backoff_cap_s: float = 0.05
    watchdog_s: Optional[float] = None

    def __post_init__(self):
        assert self.max_retries >= 0, self.max_retries
        assert self.backoff_s >= 0.0 and self.backoff_cap_s >= 0.0
        assert self.watchdog_s is None or self.watchdog_s > 0.0


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A parsed fault schedule (see ``parse_spec`` for the string form).

    Rates are per-event probabilities in ``[0, 1]``; the virtual clocks
    are event counters (fetch issues, frame allocations, decode ticks,
    fleet steps) — never wall time.
    """

    seed: int = 0
    transfer_rate: float = 0.0    # P(transient failure) per stream fetch
    stall_rate: float = 0.0       # P(in-flight transfer parks dead) per prefetch
    oom_rate: float = 0.0         # P(page-frame alloc reports OOM) per new row
    preempt_every: int = 0        # preempt one running request every N decode ticks
    kill_replica: int = -1        # replica index to kill (-1 = never)
    kill_after: int = 0           # fleet steps before the kill fires

    def __post_init__(self):
        for r in (self.transfer_rate, self.stall_rate, self.oom_rate):
            assert 0.0 <= r <= 1.0, r
        assert self.preempt_every >= 0 and self.kill_after >= 0


def parse_spec(text: str) -> FaultSpec:
    """Parse a ``REPRO_FAULTS`` / ``--faults`` spec string.

    Example: ``"seed=3,transfer=0.2,stall=0.05,oom=0.1,preempt=7,kill=1@4"``
    — seed 3; 20% transient fetch failures; 5% stalled prefetches; 10%
    page-alloc OOMs; preempt a running request every 7 decode ticks; kill
    replica 1 after 4 fleet steps.
    """
    kw: Dict[str, object] = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad faults spec item {part!r} (expect key=value)")
        key, val = (s.strip() for s in part.split("=", 1))
        if key == "seed":
            kw["seed"] = int(val)
        elif key == "transfer":
            kw["transfer_rate"] = float(val)
        elif key == "stall":
            kw["stall_rate"] = float(val)
        elif key == "oom":
            kw["oom_rate"] = float(val)
        elif key == "preempt":
            kw["preempt_every"] = int(val)
        elif key == "kill":
            replica, _, after = val.partition("@")
            kw["kill_replica"] = int(replica)
            kw["kill_after"] = int(after) if after else 1
        else:
            raise ValueError(f"unknown faults spec key {key!r} in {text!r}")
    return FaultSpec(**kw)  # type: ignore[arg-type]


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

class FaultPlan:
    """A live, armed fault schedule: deterministic draws + event ledger.

    Each injection site (``"transfer:stream-window"``,
    ``"oom"``, ...) keeps its own event counter; the n-th draw at a site
    is ``blake2b(f"{seed}:{site}:{n}") / 2**64 < rate``.  The ledger
    (``events``) counts both injected faults and the recoveries the
    serving stack reports back via ``note`` — dumped by ``report()`` /
    ``REPRO_FAULTS_REPORT``.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._counts: Dict[str, int] = {}
        self._last_fail: Dict[str, bool] = {}
        self.events: Dict[str, int] = {}

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        return cls(parse_spec(text))

    # -- deterministic draws ----------------------------------------------
    def _draw(self, site: str) -> float:
        n = self._counts.get(site, 0)
        self._counts[site] = n + 1
        digest = hashlib.blake2b(
            f"{self.spec.seed}:{site}:{n}".encode(), digest_size=8
        ).digest()
        return int.from_bytes(digest, "big") / 2.0**64

    def _fail(self, site: str, rate: float) -> bool:
        """Rate-draw at ``site``, bounded to never fail twice in a row."""
        if rate <= 0.0:
            return False
        if self._last_fail.get(site, False):
            self._last_fail[site] = False
            return False
        hit = self._draw(site) < rate
        self._last_fail[site] = hit
        return hit

    # -- injection queries (consulted by the seams) -----------------------
    def transfer_fault(self, tag: str, key) -> bool:
        if self._fail(f"transfer:{tag}", self.spec.transfer_rate):
            self.note(f"injected:transfer:{tag}")
            return True
        return False

    def stall_fault(self, tag: str, key) -> bool:
        if self._fail(f"stall:{tag}", self.spec.stall_rate):
            self.note(f"injected:stall:{tag}")
            return True
        return False

    def page_oom(self) -> bool:
        if self._fail("oom", self.spec.oom_rate):
            self.note("injected:page-oom")
            return True
        return False

    def preempt_due(self, tick: int) -> bool:
        n = self.spec.preempt_every
        if n > 0 and tick > 0 and tick % n == 0:
            self.note("injected:preempt")
            return True
        return False

    def kill_due(self, replica: int, step: int) -> bool:
        if replica == self.spec.kill_replica and step == self.spec.kill_after:
            self.note("injected:replica-kill")
            return True
        return False

    # -- recovery ledger ---------------------------------------------------
    def note(self, event: str, n: int = 1) -> None:
        self.events[event] = self.events.get(event, 0) + n

    def report(self) -> Dict[str, object]:
        return {"spec": dataclasses.asdict(self.spec),
                "events": dict(sorted(self.events.items()))}


def resolve(obj) -> Optional[FaultPlan]:
    """Coerce a ``ServeConfig.faults`` value into a plan (or ``None``).

    Accepts ``None`` / a spec string / a ``FaultSpec`` / an armed
    ``FaultPlan`` (shared plans keep one ledger across servers).
    """
    if obj is None or isinstance(obj, FaultPlan):
        return obj
    if isinstance(obj, FaultSpec):
        return FaultPlan(obj)
    if isinstance(obj, str):
        return FaultPlan.parse(obj)
    raise TypeError(f"cannot resolve faults from {type(obj).__name__}")


# --------------------------------------------------------------------------
# arming: explicit region > ambient env  (mirrors analysis.runtime)
# --------------------------------------------------------------------------

class _Shield:
    """Stack sentinel: masks the ambient plan (fault-free baseline)."""


_STACK: List[object] = []
_AMBIENT: Optional[FaultPlan] = None
_AMBIENT_INIT = False


def _dump_report(fp: FaultPlan, path: str) -> None:
    try:
        with open(path, "w") as f:
            json.dump(fp.report(), f, indent=2, sort_keys=True)
    except OSError:
        pass


def _ambient() -> Optional[FaultPlan]:
    global _AMBIENT, _AMBIENT_INIT
    if not _AMBIENT_INIT:
        _AMBIENT_INIT = True
        spec = os.environ.get("REPRO_FAULTS", "").strip()
        if spec:
            _AMBIENT = FaultPlan.parse(spec)
            path = os.environ.get("REPRO_FAULTS_REPORT", "").strip()
            if path:
                atexit.register(_dump_report, _AMBIENT, path)
    return _AMBIENT


def current() -> Optional[FaultPlan]:
    """The armed plan for this point of execution (or ``None``)."""
    if _STACK:
        top = _STACK[-1]
        return None if isinstance(top, _Shield) else top  # type: ignore[return-value]
    return _ambient()


@contextlib.contextmanager
def armed(fp):
    """Arm ``fp`` (a ``FaultPlan``) for the dynamic extent of the block.

    ``armed(None)`` is a pass-through — the ambient ``REPRO_FAULTS``
    plan (if any) stays visible, so a server built without explicit
    faults still participates in a CI chaos run.
    """
    if fp is None:
        yield None
        return
    assert isinstance(fp, FaultPlan), fp
    _STACK.append(fp)
    try:
        yield fp
    finally:
        _STACK.pop()


@contextlib.contextmanager
def shielded():
    """Mask any armed/ambient plan: the block runs fault-free."""
    _STACK.append(_Shield())
    try:
        yield
    finally:
        _STACK.pop()


def note(event: str, n: int = 1) -> None:
    """Record a recovery event on the armed plan, if any (no-op unarmed)."""
    fp = current()
    if fp is not None:
        fp.note(event, n)
