"""Parse lowered/compiled HLO for the roofline's collective term.

``compiled.cost_analysis()`` gives FLOPs and bytes accessed, but not
per-collective traffic — we sum operand sizes of every collective op in the
post-SPMD optimized HLO text.  Collectives inside ``while`` bodies (layer
scans) are multiplied by the loop trip count, recovered from the loop
condition's comparison constant (best effort, falling back to a caller
default).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"=\s*\S+\s+while\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


_CALLS_RE = re.compile(r"(?:body|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def collective_stats(
    hlo_text: str, default_trips: int = 1
) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes}.

    Collectives are attributed along the call graph from ENTRY; each
    ``while`` multiplies its body's contribution by the loop trip count
    (read from the condition's comparison constant), so nested scans
    (e.g. the KV-block scan inside the layer scan) compose multiplicatively.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    def loop_trips(cond: str) -> int:
        best = default_trips
        for cl in comps.get(cond, []):
            c = _CONST_RE.search(cl)
            if c and int(c.group(1)) > 0:
                best = int(c.group(1))
        return best

    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0}
    )

    def walk(name: str, mult: float, depth: int = 0) -> None:
        if depth > 12 or name not in comps:
            return
        for line in comps[name]:
            m = _COLL_RE.match(line)
            if m and m.group(3) != "-done":
                b = _shape_bytes(m.group(1))
                stats[m.group(2)]["count"] += mult
                stats[m.group(2)]["bytes"] += b * mult
            if " while(" in line:
                mc = _COND_RE.search(line)
                mb = re.search(r"body=%?([\w.\-]+)", line)
                if mb:
                    t = loop_trips(mc.group(1)) if mc else default_trips
                    walk(mb.group(1), mult * t, depth + 1)
            else:
                for callee in _CALLS_RE.findall(line):
                    walk(callee, mult, depth + 1)

    walk(entry, 1.0)
    return dict(stats)


def total_collective_bytes(hlo_text: str, default_trips: int = 1) -> float:
    s = collective_stats(hlo_text, default_trips)
    total = 0.0
    for kind, d in s.items():
        mult = 2.0 if kind == "all-reduce" else 1.0   # ring: RS + AG phases
        total += mult * d["bytes"]
    return total


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S+?)\s+[a-z\-]+")
_DOT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\S+?)\s+dot\("
    r"\s*%?([\w.\-]+),\s*%?([\w.\-]+)\)"
)
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


def dot_flops(hlo_text: str, default_trips: int = 1) -> float:
    """Total dot-product FLOPs along the call graph, while bodies scaled by
    trip count.  flops(dot) = 2 * prod(output dims) * prod(contracted dims).
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
    if entry is None and comps:
        entry = next(iter(comps))

    # symbol tables: per computation, name -> shape string
    symtab: Dict[str, Dict[str, str]] = {}
    for name, lines in comps.items():
        tab: Dict[str, str] = {}
        for line in lines:
            d = _DEF_RE.match(line)
            if d:
                tab[d.group(1)] = d.group(2)
        symtab[name] = tab

    def loop_trips(cond: str) -> int:
        best = default_trips
        for cl in comps.get(cond, []):
            c = _CONST_RE.search(cl)
            if c and int(c.group(1)) > 0:
                best = int(c.group(1))
        return best

    total = 0.0
    seen_guard = [0]

    def walk(name: str, mult: float, depth: int = 0) -> None:
        nonlocal total
        seen_guard[0] += 1
        if depth > 12 or name not in comps or seen_guard[0] > 200000:
            return
        tab = symtab.get(name, {})
        for line in comps[name]:
            dm = _DOT_RE.match(line)
            if dm:
                out_dims = _shape_dims(dm.group(2)) or []
                lhs_shape = tab.get(dm.group(3))
                cdims = _CDIMS_RE.search(line)
                contracted = 1
                if lhs_shape and cdims:
                    ldims = _shape_dims(lhs_shape) or []
                    for ci in (int(c) for c in cdims.group(1).split(",") if c):
                        if ci < len(ldims):
                            contracted *= ldims[ci]
                n = 1
                for d in out_dims:
                    n *= d
                total += 2.0 * n * contracted * mult
            if " while(" in line:
                mc = _COND_RE.search(line)
                mb = re.search(r"body=%?([\w.\-]+)", line)
                if mb:
                    t = loop_trips(mc.group(1)) if mc else default_trips
                    walk(mb.group(1), mult * t, depth + 1)
            else:
                for callee in _CALLS_RE.findall(line):
                    walk(callee, mult, depth + 1)

    walk(entry, 1.0)
    return total


def op_histogram(hlo_text: str, top: int = 20) -> Dict[str, int]:
    """Instruction-name histogram (diagnosing remat / redundant ops)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([a-z\-]+)\(", line)
        if m:
            counts[m.group(1)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
