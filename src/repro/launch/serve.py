"""Serving launcher: plan with the paper's search, then run the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --requests 16 --prompt-len 32 --decode-len 16 --stream-weights
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core import planner, workload as W
from repro.core.dag_builder import Plan
from repro.core.hardware import PROFILES
from repro.data.datasets import DatasetSpec, synthetic_requests
from repro.models import model as M
from repro.serving import arrivals
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import serve_dataset
from repro.serving.weights import ParamStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--profile", default="C2-A5000-512GB", choices=PROFILES)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8,
                    help="accumulated batch B for the smoke execution")
    ap.add_argument("--expert-path", default="grouped",
                    choices=("grouped", "loop"),
                    help="MoE stage: grouped dispatch vs per-expert loop")
    ap.add_argument("--scheduler", default="static",
                    choices=("static", "continuous"),
                    help="static accumulated batches vs continuous in-flight "
                         "batching (finished slots recycled mid-batch)")
    ap.add_argument("--prompt-lens", default=None,
                    help="comma-separated prompt lengths cycled over "
                         "requests (ragged workload), e.g. 16,32,24")
    ap.add_argument("--decode-lens", default=None,
                    help="comma-separated per-request decode lengths cycled "
                         "over requests, e.g. 8,32,128")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that finishes a sequence early")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (requests/s); "
                         "default is the closed-loop drain (all due at t=0)")
    ap.add_argument("--arrival-trace", default=None,
                    help="comma-separated arrival offsets in seconds, e.g. "
                         "0,0.5,1.2 (overrides --arrival-rate)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k highest logits (0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed (per-request streams are "
                         "deterministic in it)")
    ap.add_argument("--stream-weights", action="store_true",
                    help="execute through the streamed parameter store: "
                         "weights beyond the resident budget stay host-side "
                         "and are double-buffer prefetched per layer")
    ap.add_argument("--resident-gb", type=float, default=None,
                    help="device bytes (GB) of the greedy resident weight "
                         "set; implies --stream-weights (default when "
                         "streaming: 0 — the smoke model is tiny, so the "
                         "planned S_Params would pin everything and stream "
                         "nothing)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async prefetch (streamed-serial: "
                         "fetch-on-demand, copy serialized with compute)")
    ap.add_argument("--predict-topk", type=int, default=None,
                    help="predictive per-expert streaming: stream only the "
                         "k-hat experts predicted from the previous layer's "
                         "gate tap (plus demand fetches); default follows "
                         "the planned predict_topk; 0 forces whole-stack "
                         "streaming; implies --stream-weights")
    ap.add_argument("--expert-lru-gb", type=float, default=None,
                    help="hot-expert device LRU budget (GB) for predictive "
                         "streaming; default: the residency plan's spare "
                         "bytes")
    ap.add_argument("--kv-page-tokens", type=int, default=0,
                    help="page the KV cache into fixed-size blocks of this "
                         "many tokens (0 = the contiguous cache); pages "
                         "beyond the device pool budget live host-side and "
                         "stream through the prefetch window")
    ap.add_argument("--device-kv-gb", type=float, default=None,
                    help="device page-pool budget (GB); default keeps every "
                         "page frame on device (Mode A, bookkeeping only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cache shared prompt prefixes at page granularity "
                         "and admit hits by page-row copy instead of "
                         "recomputing prefill (requires --kv-page-tokens)")
    ap.add_argument("--mesh", default=None, metavar="DP,EP",
                    help="serve on a dp,ep device mesh: EP shards every "
                         "MoE layer's experts across EP devices (pipelined "
                         "all-to-all dispatch, repro.distributed) and DP "
                         "runs that engine in DP data-parallel Server "
                         "replicas behind one arrival queue; needs "
                         "DP*EP visible devices (CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--ep-chunks", type=int, default=None,
                    help="expert-parallel pipeline chunk count (a2a of "
                         "chunk k+1 overlaps expert FFN of chunk k); "
                         "default: the planner's pick for the mesh")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm deterministic fault injection for the run, "
                         "e.g. 'seed=3,transfer=0.2,stall=0.05,oom=0.1,"
                         "preempt=7,kill=1@4' (repro.faults spec grammar); "
                         "recovery is exercised and counted — retried "
                         "transfers, preempt/resume checkpoints, replica "
                         "failover — and the served tokens stay identical "
                         "to the unarmed run")
    ap.add_argument("--sanitize", default="off",
                    choices=("off", "log", "strict"),
                    help="run serving under the analysis sanitizer: decode "
                         "regions execute with jax.transfer_guard (strict "
                         "raises on unplanned transfers, log records them) "
                         "and donation aliasing is verified; prints the "
                         "sanitizer report after serving")
    args = ap.parse_args()

    hw = PROFILES[args.profile]

    dp = ep = 1
    if args.mesh:
        try:
            dp, ep = (int(x) for x in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh wants DP,EP (got {args.mesh!r})")
        if dp < 1 or ep < 1:
            raise SystemExit(f"--mesh axes must be >= 1 (got {args.mesh!r})")
        if len(jax.devices()) < ep:
            raise SystemExit(
                f"--mesh {args.mesh} needs {ep} devices for the expert-"
                f"parallel axis but only {len(jax.devices())} are visible; "
                "on CPU set XLA_FLAGS=--xla_force_host_platform_device_"
                "count=8 before launch")
        if args.stream_weights or args.resident_gb is not None \
                or args.predict_topk is not None:
            raise SystemExit("--mesh serves fully-resident replicas; it "
                             "composes with neither --stream-weights nor "
                             "predictive streaming")

    # 1. plan on the FULL config with the paper's search
    full = get_config(args.arch)
    res = planner.search_decode(
        full, hw, ctx=args.prompt_len + args.decode_len,
        decode_len=args.decode_len, scheduler=args.scheduler,
        mesh_shape=(dp, ep) if args.mesh else None,
    )
    print(f"planned ({full.name} on {hw.name}): {res.plan.describe()}")
    rp_full = W.plan_residency(full, res.plan.s_params)
    print(f"planned residency: {rp_full.resident_bytes/1e9:.1f}GB resident "
          f"of {W.model_bytes(full)/1e9:.1f}GB model "
          f"({rp_full.n_streamed()} modules streamed, stream window "
          f"{res.plan.s_expert/1e9:.1f}GB)")
    print(f"predicted decode throughput: {res.estimate.throughput:.0f} tok/s")

    # 2. execute module-based batching at smoke scale with the same shape
    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = DatasetSpec("serve", args.requests, args.prompt_len, args.decode_len)
    parse = lambda s: [int(x) for x in s.split(",")] if s else None
    times = None
    if args.arrival_trace:
        times = arrivals.trace([float(x) for x in args.arrival_trace.split(",")])
    elif args.arrival_rate is not None:
        times = arrivals.poisson(args.requests, args.arrival_rate,
                                 seed=args.seed)
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              seed=args.seed)
    requests = synthetic_requests(
        spec, cfg.vocab_size,
        prompt_lens=parse(args.prompt_lens),
        decode_lens=parse(args.decode_lens),
        arrivals=times,
        sampling=sampling if not sampling.is_greedy else None,
    )
    plan = Plan(
        B=args.batch,
        b_a=max(1, min(res.plan.b_a, args.batch)),
        # b_e is a per-expert capacity; the engine clamps it to the
        # accumulated batch, so the planned value carries over directly
        b_e=res.plan.b_e,
        omega=res.plan.omega if cfg.has_attention else 0.0,
        s_params=res.plan.s_params,
        s_expert=res.plan.s_expert,
        predict_topk=res.plan.predict_topk,
        ep_chunks=(args.ep_chunks if args.ep_chunks
                   else res.plan.ep_chunks),
    )
    # re-plan the fused chunk T at the smoke batch (the admission cadence
    # scales with B, so the full-config T would over- or under-chunk here)
    from dataclasses import replace as dc_replace

    plan = dc_replace(plan, decode_chunk=planner.select_decode_chunk(
        plan, args.decode_len, scheduler=args.scheduler,
    ))
    print(f"fused decode chunk T={plan.decode_chunk} "
          f"({args.scheduler} cadence at B={plan.B})")
    # --resident-gb implies streaming; at smoke scale the full-model
    # S_Params would pin everything, so the streamed smoke run defaults to
    # resident_bytes=0 to actually exercise the stream path
    stream = (args.stream_weights or args.resident_gb is not None
              or args.predict_topk is not None)
    resident_bytes = (
        0.0 if args.resident_gb is None else args.resident_gb * 1e9
    )
    store = None
    if stream:
        # the ONE store every scheduler engine executes through — built
        # here so the realized split can be printed before serving
        khat = (plan.predict_topk if args.predict_topk is None
                else args.predict_topk)
        store = ParamStore(
            cfg, params, resident_bytes=resident_bytes,
            prefetch=not args.no_prefetch,
            predict_topk=khat,
            lru_bytes=(None if args.expert_lru_gb is None
                       else args.expert_lru_gb * 1e9),
        )
        print(f"realized residency (smoke): {store.describe()}")
    if args.kv_page_tokens:
        # page-pool residency at the serving shape, printed up front (the
        # table the scheduler's engines will build)
        from repro.serving.cache import CacheConfig, KVPageTable

        probe = KVPageTable(
            cfg,
            [(cfg.layer_kind(i), cfg.ffn_kind(i))
             for i in range(cfg.num_layers)],
            args.batch, args.prompt_len + args.decode_len,
            CacheConfig(
                page_tokens=args.kv_page_tokens,
                device_pool_bytes=(None if args.device_kv_gb is None
                                   else args.device_kv_gb * 1e9),
            ),
        )
        print(f"page-pool residency (smoke): {probe.describe()}")
    import contextlib

    from repro import analysis

    sctx = None
    if args.mesh and ep > 1:
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.specs import ShardCtx

        sctx = ShardCtx(mesh=make_debug_mesh(1, ep), batch_axes=("data",),
                        model_axis="model", moe_dispatch="a2a")
        print(f"mesh: dp={dp} replicas x ep={ep} expert-parallel ranks, "
              f"ep_chunks={plan.ep_chunks}")

    san_ctx = (analysis.sanitize(strict=args.sanitize == "strict",
                                 donation=True)
               if args.sanitize != "off" else contextlib.nullcontext())
    per_replica = None
    with san_ctx as san:
        if dp > 1:
            # data-parallel fan-out: one arrival queue over dp Server
            # replicas (shared prefix keys, per-replica KV/engines)
            from repro.distributed import ReplicaServer
            from repro.serving.server import ServeConfig

            rserver = ReplicaServer(
                cfg, params, dp, plan=plan,
                serve=ServeConfig(
                    scheduler=args.scheduler, decode_len=args.decode_len,
                    eos_id=args.eos_id, expert_path=args.expert_path,
                    hw=hw if args.scheduler == "continuous" else None,
                    kv_page_tokens=args.kv_page_tokens,
                    device_kv_gb=args.device_kv_gb,
                    prefix_cache=args.prefix_cache,
                    sctx=sctx, ep_chunks=plan.ep_chunks,
                    faults=args.faults,
                ),
            )
            for r in requests:
                rserver.submit(r)
            rrep = rserver.run()
            report, per_replica = rrep.merged, rrep.per_replica
        else:
            report = serve_dataset(
                cfg, params, requests, plan, args.decode_len,
                expert_path=args.expert_path,
                scheduler=args.scheduler, eos_id=args.eos_id,
                store=store,
                hw=hw if args.scheduler == "continuous" else None,
                kv_page_tokens=args.kv_page_tokens,
                device_kv_gb=args.device_kv_gb,
                prefix_cache=args.prefix_cache,
                sctx=sctx, ep_chunks=plan.ep_chunks,
                faults=args.faults)
    if san is not None:
        rep = san.report()
        planned = ", ".join(f"{k}={v}" for k, v in
                            sorted(rep["planned_transfers"].items())) or "none"
        bad = [d for d in rep["donation_checks"] if not d["ok"]]
        print(f"sanitizer[{rep['mode']}]: planned transfers: {planned}")
        print(f"sanitizer: donation checks "
              f"{len(rep['donation_checks']) - len(bad)}/"
              f"{len(rep['donation_checks'])} ok; "
              f"steady retraces: {sum(rep['steady_retraces'].values())}")
    print(f"served {args.requests} requests in {report.total_s:.2f}s "
          f"({report.decode_throughput:.1f} decode tok/s on this host, "
          f"{report.expert_tokens_dropped} routed copies dropped)")
    print(f"[{report.scheduler}] decode slot-steps: {report.decode_slot_steps} "
          f"(wasted {report.wasted_slot_steps}, "
          f"occupancy {report.occupancy:.0%}); "
          f"mean request latency {report.mean_latency_s:.2f}s")
    if per_replica is not None:
        for i, r in enumerate(per_replica):
            print(f"replica[{i}]: {len(r.request_results)} requests, "
                  f"{r.decode_throughput:.1f} decode tok/s, "
                  f"occupancy {r.occupancy:.0%}, "
                  f"a2a {r.a2a_gb:.4f}GB")
    if report.collective_dispatches:
        print(f"expert-parallel a2a: {report.a2a_gb:.4f}GB exchanged over "
              f"{report.collective_dispatches} collective dispatches "
              f"(ep={ep}, chunks={plan.ep_chunks})")
    print(f"TTFT p50/p95: {report.ttft_percentile(50):.3f}/"
          f"{report.ttft_percentile(95):.3f}s; "
          f"TPOT p50/p95: {report.tpot_percentile(50)*1e3:.1f}/"
          f"{report.tpot_percentile(95)*1e3:.1f}ms; "
          f"mean queue wait {report.mean_queue_wait_s:.3f}s")
    if stream:
        print(f"weight streaming: {report.htod_gb:.3f}GB htod, "
              f"prefetch stall {report.prefetch_wait_s:.3f}s")
    if report.expert_load is not None:
        per_expert = report.expert_load.sum(axis=0)
        hist = "/".join(str(int(c)) for c in per_expert)
        print(f"routing skew: {report.routing_skew:.2f}x balanced "
              f"(per-expert routed copies {hist})")
        drops = "/".join(
            str(int(d)) for d in report.expert_dropped_by_layer
        )
        print(f"per-MoE-layer drops: {drops} "
              f"({report.capacity_replans} online capacity re-plans)")
    if report.expert_pred_hits or report.expert_pred_misses \
            or report.expert_lru_hits:
        print(f"predictive expert streaming: "
              f"pred hit rate {report.pred_hit_rate:.0%} "
              f"({report.expert_pred_hits} staged / "
              f"{report.expert_pred_misses} demand), "
              f"LRU hit rate {report.lru_hit_rate:.0%} "
              f"({report.expert_lru_hits} hits)")
    if args.kv_page_tokens:
        print(f"kv paging: {report.kv_htod_bytes / 1e6:.3f}MB page htod, "
              f"{report.kv_dtoh_bytes / 1e6:.3f}MB dtoh")
        if args.prefix_cache:
            print(f"prefix cache: {report.prefix_hits} hits / "
                  f"{report.prefix_hits + report.prefix_misses} lookups "
                  f"(hit rate {report.prefix_hit_rate:.0%})")
    if report.admission_deferrals:
        print(f"admissions deferred by the Eq. 2 host KV budget: "
              f"{report.admission_deferrals}")
    if args.faults or report.transfer_retries or report.preemptions \
            or report.failovers:
        print(f"fault recovery: {report.transfer_retries} transfer retries, "
              f"{report.transfer_timeouts} watchdog timeouts; "
              f"{report.preemptions} preemptions / {report.resumes} resumes; "
              f"{report.failovers} replica failovers "
              f"({report.requeued_requests} requests requeued)")
        if report.degrade_deferrals or report.page_demotions \
                or report.chunk_shrinks:
            print(f"memory-pressure degradation: "
                  f"{report.degrade_deferrals} admission deferrals, "
                  f"{report.page_demotions} pages demoted to host, "
                  f"{report.chunk_shrinks} decode-chunk shrinks")


if __name__ == "__main__":
    main()
