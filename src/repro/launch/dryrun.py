import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits — with no real hardware.

For each combination the appropriate step function (train_step /
prefill_step / serve_step) is jit'd with production in_shardings, lowered
against ShapeDtypeStruct inputs (no allocation), compiled for the
256-chip single-pod mesh and the 512-chip 2-pod mesh, and the compiled
artifact's memory_analysis / cost_analysis / collective schedule is recorded
to reports/dryrun/*.json for the roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs  # noqa: E402
from repro.configs.base import ModelConfig, ShapeSpec  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh  # noqa: E402
from repro.models import model as model_mod  # noqa: E402
from repro.models.frontends import frontend_spec  # noqa: E402
from repro.sharding.specs import ShardCtx, cache_shardings, param_shardings  # noqa: E402
from repro.train.optimizer import adamw_init  # noqa: E402
from repro.train.train_loop import make_train_step  # noqa: E402

REPORT_DIR = os.path.join(os.path.dirname(__file__), "../../../reports/dryrun")


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (see DESIGN.md §4)"
        )
    return None


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: model_mod.init_params(cfg, k), jax.random.PRNGKey(0)
    )


def _named(ctx: ShardCtx, *logical, shape):
    return NamedSharding(ctx.mesh, ctx.spec(*logical, shape=shape))


def build_case(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx,
               weights: str = "fsdp"):
    """Returns (fn, abstract_args, in_shardings, scan_trips)."""
    zero1 = weights == "fsdp"
    params = abstract_params(cfg)
    pspecs = param_shardings(ctx, params, zero1=zero1)
    B, S = shape.global_batch, shape.seq_len
    fe = frontend_spec(cfg, B)
    G = model_mod.num_groups(cfg)

    if shape.kind == "train":
        opt = jax.eval_shape(adamw_init, params)
        ospecs = type(opt)(
            NamedSharding(ctx.mesh, P()),
            param_shardings(ctx, opt.mu, zero1=zero1),
            param_shardings(ctx, opt.nu, zero1=zero1),
        )
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tspec = _named(ctx, "batch", None, shape=(B, S))
        step = make_train_step(cfg, ctx, remat=True)
        args = [params, opt, tokens, labels]
        shards = [pspecs, ospecs, tspec, tspec]
        if fe is not None:
            args.append(fe)
            shards.append(_named(ctx, "batch", None, None, shape=fe.shape))
        return step, args, shards, G

    if shape.kind == "prefill":
        def prefill_step(params, tokens, frontend_emb=None):
            return model_mod.prefill(cfg, params, tokens, frontend_emb, ctx)

        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        args = [params, tokens]
        shards = [pspecs, _named(ctx, "batch", None, shape=(B, S))]
        if fe is not None:
            args.append(fe)
            shards.append(_named(ctx, "batch", None, None, shape=fe.shape))
        return prefill_step, args, shards, G

    # decode: ONE new token against a cache of seq_len
    def serve_step(params, cache, tokens, pos):
        return model_mod.decode_step(cfg, params, cache, tokens, pos, ctx)

    cache = jax.eval_shape(lambda: model_mod.init_cache(cfg, B, S))
    cspecs = cache_shardings(ctx, cache)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = [params, cache, tokens, pos]
    shards = [
        pspecs, cspecs,
        _named(ctx, "batch", shape=(B,)),
        NamedSharding(ctx.mesh, P()),
    ]
    return serve_step, args, shards, G


def run_case(arch: str, shape_name: str, multi_pod: bool,
             weights: str = "fsdp", save: bool = True,
             seq_shard: bool | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "weights": weights, "status": "ok",
    }
    reason = skip_reason(cfg, shape)
    if reason:
        out["status"] = "skipped"
        out["reason"] = reason
        return _save(out) if save else out

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if seq_shard is None:
        seq_shard = shape.kind == "train"
    out["seq_shard"] = seq_shard
    ctx = make_ctx(mesh, seq_shard=seq_shard)
    try:
        fn, args, shards, trips = build_case(cfg, shape, ctx, weights)
        jitted = jax.jit(fn, in_shardings=tuple(shards))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = hlo_analysis.collective_stats(hlo, default_trips=trips)
        out.update(
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=mesh.devices.size,
            memory={
                k: getattr(mem, k, None)
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
            } if mem is not None else None,
            flops=float(cost.get("flops", -1.0)) if cost else None,
            bytes_accessed=float(cost.get("bytes accessed", -1.0)) if cost else None,
            collectives=colls,
            collective_bytes=hlo_analysis.total_collective_bytes(hlo, trips),
            dot_flops_per_device=hlo_analysis.dot_flops(hlo, trips),
            scan_trips=trips,
        )
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        out["status"] = "failed"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
    return _save(out) if save else out


def _save(out: dict) -> dict:
    os.makedirs(REPORT_DIR, exist_ok=True)
    default_sp = out.get("shape") == "train_4k"
    suffix = ""
    if out.get("seq_shard") is not None and out["seq_shard"] != default_sp:
        suffix = "_sp" if out["seq_shard"] else "_nosp"
    name = f"{out['arch']}_{out['shape']}_{out['mesh']}_{out['weights']}{suffix}.json"
    with open(os.path.join(REPORT_DIR, name), "w") as f:
        json.dump(out, f, indent=2, default=str)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--weights", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                fname = os.path.join(
                    REPORT_DIR,
                    f"{arch}_{shape}_{mesh_name}_{args.weights}.json",
                )
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {arch} {shape} {mesh_name}")
                    continue
                r = run_case(arch, shape, multi, args.weights)
                mem = (r.get("memory") or {}).get("temp_size_in_bytes")
                print(
                    f"[{r['status']:7s}] {arch:24s} {shape:12s} {mesh_name:6s}"
                    f" compile={r.get('compile_s', '-'):>6}s"
                    f" temp={mem if mem is not None else '-'}"
                    f" {r.get('error', r.get('reason', ''))[:90]}"
                )


if __name__ == "__main__":
    main()
