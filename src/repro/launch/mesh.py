"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure
data parallelism over DCN.

Defined as functions so importing this module never touches jax device
state (device count locks on first backend init).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.sharding.specs import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(mesh, *, seq_shard: bool = False) -> ShardCtx:
    names = mesh.axis_names
    batch = tuple(n for n in names if n in ("pod", "data"))
    model = "model" if "model" in names else None
    return ShardCtx(
        mesh=mesh, batch_axes=batch, model_axis=model, seq_shard=seq_shard
    )


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
