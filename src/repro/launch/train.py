"""Training launcher.

On this CPU container it runs the smoke-scale configs for real; on a TPU
slice the same entry point builds the production mesh and shards
params/optimizer per DESIGN.md §5.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 20
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.datasets import synthetic_batches
from repro.launch.mesh import make_ctx
from repro.models import model as M
from repro.sharding.specs import ShardCtx, param_shardings
from repro.train.train_loop import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (requires a real TPU slice)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    n_dev = jax.device_count()
    if n_dev > 1:
        data = max(1, n_dev // 16)
        mesh = jax.make_mesh((data, n_dev // data), ("data", "model"))
        ctx = make_ctx(mesh, seq_shard=True)
        print(f"mesh: {dict(mesh.shape)}")
    else:
        ctx = ShardCtx()

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if ctx.mesh is not None:
        shardings = param_shardings(ctx, params, zero1=True)
        params = jax.device_put(params, shardings)  # lint: allow[MG105] init-time sharded placement, not a serving-path transfer
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps "
          f"of {args.batch}x{args.seq} on {n_dev} device(s)")
    batches = iter(
        (jnp.asarray(t), jnp.asarray(l))
        for t, l in synthetic_batches(cfg.vocab_size, args.batch, args.seq)
    )
    train_loop(
        cfg, params, batches, steps=args.steps, ctx=ctx, lr=args.lr,
        log_every=max(1, args.steps // 10),
        checkpoint_path=args.checkpoint,
        checkpoint_every=0 if not args.checkpoint else max(10, args.steps // 2),
    )


if __name__ == "__main__":
    main()
