"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:

  compute term    = HLO_dot_FLOPs_per_chip / peak_FLOP/s
  memory term     = HBM_bytes_per_chip      / HBM_bw
  collective term = collective_bytes_per_chip / ICI_link_bw

HLO FLOPs and collective bytes are parsed from the compiled module
(launch/hlo_analysis walks the call graph and scales while-bodies by their
trip counts; XLA-CPU's cost_analysis() does not traverse loop bodies, which
we verified undercounts by ~1e4x).  HBM traffic is analytic (weights
streamed per pass, cache reads, residual activations) because byte-level
traffic of fused loops is not recoverable from HLO text; the formulas are
below and deliberately conservative.

Usage:
    python -m repro.launch.roofline [--write reports/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import workload as W

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9
REPORT_DIR = os.path.join(os.path.dirname(__file__), "../../../reports/dryrun")

N_DEV_SINGLE = 256
MODEL_PAR = 16
DATA_PAR = 16


# ---------------------------------------------------------------------------
# Analytic HBM traffic per device per step
# ---------------------------------------------------------------------------
def memory_bytes_per_device(cfg: ModelConfig, shape: ShapeSpec,
                            weights: str = "fsdp") -> Dict[str, float]:
    """Per-device HBM bytes for one step (components + total)."""
    n_dev = N_DEV_SINGLE
    model_b = W.model_bytes(cfg)
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(1, B // DATA_PAR)

    if shape.kind == "train":
        # fwd + remat-fwd + bwd weight reads; FSDP gathers write once more.
        w_passes = 4.0 if weights == "fsdp" else 3.0
        w_bytes = model_b * w_passes / (MODEL_PAR if weights == "tp" else 1)
        # optimizer state read+write (f32 m, v) + grads, fully sharded
        opt_bytes = cfg.param_counts()["total"] * (4 + 4 + 4) * 2 / n_dev
        act = 12 * cfg.num_layers * b_loc * S * cfg.d_model * 2 / MODEL_PAR
        total = w_bytes + opt_bytes + act
        return {"weights": w_bytes, "opt": opt_bytes, "act": act,
                "total": total}

    if shape.kind == "prefill":
        w_bytes = model_b / (MODEL_PAR if weights == "tp" else 1)
        act = 12 * cfg.num_layers * b_loc * S * cfg.d_model * 2 / MODEL_PAR
        kv_w = b_loc * W.kv_bytes_per_seq(cfg, S) / MODEL_PAR * DATA_PAR / DATA_PAR
        total = w_bytes + act + kv_w
        return {"weights": w_bytes, "act": act, "kv": kv_w, "total": total}

    # decode: one token; weights + full cache read dominate
    w_bytes = model_b / (MODEL_PAR if weights == "tp" else 1)
    kv = B * W.kv_bytes_per_seq(cfg, S) / n_dev * DATA_PAR  # sharded B/data, heads/model
    act = 8 * cfg.num_layers * b_loc * cfg.d_model * 2
    total = w_bytes + kv + act
    return {"weights": w_bytes, "kv": kv, "act": act, "total": total}


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.param_counts()["active"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# Assemble the table
# ---------------------------------------------------------------------------
@dataclass
class RooflineRow:
    arch: str
    shape: str
    status: str
    compute_s: Optional[float] = None
    memory_s: Optional[float] = None
    collective_s: Optional[float] = None
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: Optional[float] = None
    note: str = ""


LEVERS = {
    "compute": "cut implementation FLOP waste (causal block skipping / "
               "lower capacity factor / no remat recompute)",
    "memory": "keep weights resident (TP instead of FSDP) or batch more "
              "tokens per weight read — the paper's module-batching insight",
    "collective": "reshard: fewer all-gathers (weight-stationary), bf16 "
                  "collectives, or all-to-all expert dispatch",
}


def load_report(arch: str, shape: str, mesh: str = "single",
                weights: str = "fsdp") -> Optional[dict]:
    path = os.path.join(REPORT_DIR, f"{arch}_{shape}_{mesh}_{weights}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def build_rows(weights: str = "fsdp") -> List[RooflineRow]:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            rep = load_report(arch, shape_name, "single", weights)
            if rep is None:
                rows.append(RooflineRow(arch, shape_name, "missing"))
                continue
            if rep["status"] == "skipped":
                rows.append(RooflineRow(arch, shape_name, "skipped",
                                        note=rep["reason"]))
                continue
            if rep["status"] != "ok":
                rows.append(RooflineRow(arch, shape_name, "failed",
                                        note=rep.get("error", "")[:80]))
                continue
            dot = rep.get("dot_flops_per_device") or 0.0
            coll = rep.get("collective_bytes") or 0.0
            mem = memory_bytes_per_device(cfg, shape, weights)
            c_t = dot / PEAK_FLOPS
            m_t = mem["total"] / HBM_BW
            i_t = coll / ICI_BW
            terms = {"compute": c_t, "memory": m_t, "collective": i_t}
            dom = max(terms, key=terms.get)
            mf = model_flops(cfg, shape)
            hlo_global = dot * N_DEV_SINGLE
            rows.append(RooflineRow(
                arch, shape_name, "ok", c_t, m_t, i_t, dom, mf, hlo_global,
                (mf / hlo_global) if hlo_global else None,
                LEVERS[dom],
            ))
    return rows


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1.0:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def to_markdown(rows: List[RooflineRow], weights: str) -> str:
    out = [
        f"### Roofline — single pod (16x16 = 256 chips, weights={weights})",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL_FLOPS | useful% | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.status != "ok":
            out.append(
                f"| {r.arch} | {r.shape} | — | — | — | {r.status} | — | — | "
                f"{r.note[:70]} |"
            )
            continue
        useful = f"{100*r.useful_ratio:.0f}%" if r.useful_ratio else "-"
        out.append(
            f"| {r.arch} | {r.shape} | {fmt_s(r.compute_s)} | "
            f"{fmt_s(r.memory_s)} | {fmt_s(r.collective_s)} | "
            f"**{r.dominant}** | {r.model_flops:.2e} | "
            f"{useful} | {r.note[:70]} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--weights", default="fsdp")
    ap.add_argument("--write", default=None)
    args = ap.parse_args()
    rows = build_rows(args.weights)
    md = to_markdown(rows, args.weights)
    print(md)
    if args.write:
        os.makedirs(os.path.dirname(args.write) or ".", exist_ok=True)
        with open(args.write, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
