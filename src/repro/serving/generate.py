"""Reference generation loop (model-based batching).

This is the baseline execution order every offloading baseline shares: one
unified batch through the whole model, prefill then auto-regressive decode.
The module-batching engine (core/engine.py) must produce identical tokens.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.serving.kvcache import cache_from_prefill
from repro.serving.sampling import greedy
from repro.sharding.specs import ShardCtx


def greedy_generate(
    cfg: ModelConfig,
    params,
    tokens: jax.Array,                 # (B, S) prompt
    decode_len: int,
    frontend_emb: Optional[jax.Array] = None,
    ctx: ShardCtx = ShardCtx(),
) -> jax.Array:
    """Returns (B, decode_len) generated tokens (greedy)."""
    B, S = tokens.shape
    logits, caches = model_mod.prefill(cfg, params, tokens, frontend_emb, ctx)
    cache = cache_from_prefill(cfg, caches, S, max_seq=S + decode_len)
    out = [greedy(logits[:, 0])]
    for t in range(decode_len - 1):
        logits, cache = model_mod.decode_step(
            cfg, params, cache, out[-1], jnp.int32(S + t), ctx
        )
        out.append(greedy(logits))
    return jnp.stack(out, axis=1)
