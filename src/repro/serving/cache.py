"""Paged tiered KV cache: the first-class cache API (ROADMAP "Tiered KV").

The paper's Eq. 2 admission story says tokens accumulate in HOST memory and
only the working set lives on device — but a monolithic ``(B, max_seq)``
KV buffer pins every sequence's full extent on device, so admission gates
on device memory long before the host tier is exhausted.  This module
pages the KV cache into fixed-size ``page_tokens`` blocks behind a
``KVPageTable`` that owns the slot<->page mapping and free lists:

* **Mode A (fully device-resident).**  When the device pool budget covers
  every frame (``device_pool_bytes=None`` or large), the table is
  bookkeeping only: the engine keeps its contiguous per-layer buffers and
  the fused donated decode path stays BIT-identical — paging costs nothing
  when everything fits (the fused/streamed path-selection contract).
* **Mode B (host tier).**  When the budget covers only ``P`` frames, the
  remaining frames live in numpy host pools.  Decode falls back to the
  per-layer loop (exactly like streamed weights): each attention layer's
  host frames stream device-ward through the SAME double-buffered async
  ``device_put`` window ``ParamStore`` uses for weights
  (``serving.weights.StreamWindow``), the gather reassembles each row's
  ``span`` from device pool + streamed frames, and the ω host-attention
  rows read their pages host-side — per-page placement generalizes the ω
  split (host rows prefer host frames; device rows prefer device frames;
  either spills into the other tier).

On top of the page table, ``PrefixStore`` caches shared prompt prefixes at
page granularity: a hit is admitted by copying stored page rows instead of
recomputing prefill for the shared span (the engine's suffix-prefill
launches are independent of the prefix length).

Ownership/donation contract: the table owns the page pools the way the
engine owns the cache pytree — pool buffers are DONATED to the paged
decode modules and rebound from their results each launch; callers must
never retain references into ``pool_k``/``pool_v`` across a decode tick
(take ``np.asarray`` copies instead).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.analysis import runtime as sanitizer
from repro.configs.base import ModelConfig
from repro.serving.weights import StreamWindow


@dataclass(frozen=True)
class CacheConfig:
    """Cache-side knobs, frozen (the ``ServeConfig`` of the KV tier).

    ``page_tokens=0`` disables paging entirely (the legacy contiguous
    cache).  ``device_pool_bytes=None`` keeps every page frame on device
    (Mode A); a finite budget sizes the device pool and spills the
    remainder to the host tier (Mode B).  ``prefix_cache`` enables the
    ``PrefixStore`` (requires ``page_tokens > 0``; prefixes are keyed at
    page granularity)."""

    page_tokens: int = 0
    device_pool_bytes: Optional[float] = None
    prefix_cache: bool = False
    prefix_entries: int = 64
    prefetch: bool = True
    prefetch_depth: int = 2

    def __post_init__(self) -> None:
        assert self.page_tokens >= 0, self.page_tokens
        if self.prefix_cache:
            assert self.page_tokens > 0, (
                "prefix_cache requires paging (page_tokens > 0): prefixes "
                "are shared at page granularity"
            )

    @property
    def enabled(self) -> bool:
        return self.page_tokens > 0


class KVPageTable:
    """Slot<->page mapping, free lists, and the tiered page pools.

    One table serves every attention layer of the engine's schema: the
    ``page_map`` (batch, pages_per_seq) is shared — a batch row's page *i*
    lives in the same frame id across layers — while each attention layer
    owns its own pool buffers (frames hold per-layer K/V values).

    Frame-id encoding in ``page_map``: ``-1`` free/unallocated;
    ``0 <= f < device_frames`` device frame ``f``; ``f >= device_frames``
    host frame ``f - device_frames``.  The device pools carry ONE extra
    frame at index ``device_frames`` — the **null frame**, a write sink
    for rows whose written page lives host-side (their in-launch scatter
    lands there and is discarded; the real value is written into the host
    pool by the engine).  Nothing live ever reads the null frame.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        schema: Sequence[Tuple[str, str]],
        batch: int,
        max_seq: int,
        cache_cfg: CacheConfig,
    ) -> None:
        assert cache_cfg.enabled, "KVPageTable requires page_tokens > 0"
        self.cfg = cfg
        self.cc = cache_cfg
        self.batch = batch
        self.attn_layers: List[int] = [
            li for li, (kind, _) in enumerate(schema) if kind == "attn"
        ]
        self.n_layers = len(schema)
        sw = cfg.sliding_window
        self.span = min(max_seq, sw) if sw else max_seq
        pt = cache_cfg.page_tokens
        self.page_tokens = pt
        self.pages_per_seq = -(-self.span // pt)          # ceil
        self.total_frames = batch * self.pages_per_seq
        K, hd = cfg.num_kv_heads, cfg.head_dim
        self._dtype = jnp.dtype(cfg.dtype)
        itemsize = self._dtype.itemsize
        # one frame across every attention layer, K + V
        self.frame_bytes = (
            len(self.attn_layers) * 2 * pt * K * hd * itemsize
        )
        budget = cache_cfg.device_pool_bytes
        if budget is None:
            self.device_frames = self.total_frames
        else:
            self.device_frames = max(
                0, min(self.total_frames, int(budget // max(1, self.frame_bytes)))
            )
        self.host_frames = self.total_frames - self.device_frames
        # -1 = free; [0, P) device; P + h = host frame h
        self.page_map = np.full((batch, self.pages_per_seq), -1, np.int32)
        self._free_dev: List[int] = list(range(self.device_frames))[::-1]
        self._free_host: List[int] = list(range(self.host_frames))[::-1]
        self.pool_k: Dict[int, jax.Array] = {}
        self.pool_v: Dict[int, jax.Array] = {}
        self.host_k: Dict[int, np.ndarray] = {}
        self.host_v: Dict[int, np.ndarray] = {}
        self._window: Optional[StreamWindow] = None
        self._epoch: Dict[int, int] = {}
        self.dtoh_bytes = 0
        if not self.fully_resident:
            P = self.device_frames
            for li in self.attn_layers:
                # +1: the null write-sink frame at index P
                self.pool_k[li] = jnp.zeros((P + 1, pt, K, hd), self._dtype)
                self.pool_v[li] = jnp.zeros((P + 1, pt, K, hd), self._dtype)
                self.host_k[li] = np.zeros((self.host_frames, pt, K, hd),
                                           self._dtype)
                self.host_v[li] = np.zeros((self.host_frames, pt, K, hd),
                                           self._dtype)
                self._epoch[li] = 0
            self._window = StreamWindow(
                self._fetch_layer, depth=cache_cfg.prefetch_depth,
                enabled=True,
            )

    # -- residency -------------------------------------------------------
    @property
    def fully_resident(self) -> bool:
        """True when every page frame fits the device pool — the paging
        analogue of ``ParamStore.fully_resident``, and (with it) the
        precondition for the engine's fused decode path: host-tier pages
        keep the per-layer loop so the page stream has a layer boundary to
        hide behind."""
        return self.host_frames == 0

    def device_pool_bytes(self) -> int:
        if self.fully_resident:
            return self.total_frames * self.frame_bytes
        return (self.device_frames + 1) * self.frame_bytes

    def host_pool_bytes(self) -> int:
        return self.host_frames * self.frame_bytes

    def describe(self) -> str:
        live = int((self.page_map >= 0).sum())
        host_live = int((self.page_map >= self.device_frames).sum())
        return (
            f"pages {self.page_tokens} tok x {self.pages_per_seq}/seq: "
            f"{self.device_frames}/{self.total_frames} frames device "
            f"({self.device_pool_bytes() / 1e9:.3f}GB), "
            f"{self.host_frames} host, live={live} (host {host_live})"
        )

    # -- allocation ------------------------------------------------------
    def _alloc_frame(self, prefer_host: bool) -> int:
        a, b = ((self._free_host, self._free_dev) if prefer_host
                else (self._free_dev, self._free_host))
        first_is_host = prefer_host
        if a:
            f = a.pop()
            return self.device_frames + f if first_is_host else f
        if not b:
            raise faults.PageAllocOOM(
                "page table out of frames (batch rows exceed capacity?)")
        f = b.pop()
        return f if first_is_host else self.device_frames + f

    def ensure_rows(self, rows: Sequence[int],
                    prefer_host: Optional[Sequence[bool]] = None) -> None:
        """Allocate page frames for ``rows`` (no-op for already-allocated
        rows — re-inserting into a live slot reuses its placement).
        ``prefer_host[i]`` biases row ``i`` toward the host tier (the ω
        host-attention rows); either tier spills into the other.

        Allocation is transactional per row: on ``PageAllocOOM`` (real
        frame exhaustion, or an injected fault from the armed plan) the
        partially-allocated row is rolled back before the error
        propagates, so the admission layer can defer/degrade and retry
        without leaking frames."""
        for i, r in enumerate(rows):
            if self.page_map[r, 0] >= 0:
                continue
            fp = faults.current()
            if fp is not None and fp.page_oom():
                raise faults.PageAllocOOM(
                    f"injected page-alloc OOM (row {r})")
            ph = bool(prefer_host[i]) if prefer_host is not None else False
            try:
                for pp in range(self.pages_per_seq):
                    self.page_map[r, pp] = self._alloc_frame(ph)
            except faults.PageAllocOOM:
                self.free_rows([r])
                raise
        self._bump_all()

    def free_rows(self, rows: Sequence[int]) -> None:
        """Return ``rows``' frames to the free lists (slot recycling)."""
        for r in rows:
            for pp in range(self.pages_per_seq):
                f = int(self.page_map[r, pp])
                if f < 0:
                    continue
                if f < self.device_frames:
                    self._free_dev.append(f)
                else:
                    self._free_host.append(f - self.device_frames)
                self.page_map[r, pp] = -1
        self._bump_all()

    def _bump_all(self) -> None:
        for li in self._epoch:
            self._epoch[li] += 1

    # -- page content (Mode B) -------------------------------------------
    def _paged(self, aligned: jax.Array) -> jax.Array:
        """(n, span, K, hd) -> (n, pages_per_seq, page_tokens, K, hd)."""
        n, span, K, hd = aligned.shape
        full = self.pages_per_seq * self.page_tokens
        if full > span:
            aligned = jnp.pad(aligned,
                              ((0, 0), (0, full - span), (0, 0), (0, 0)))
        return aligned.reshape(n, self.pages_per_seq, self.page_tokens, K, hd)

    def insert_rows(self, li: int, nk: jax.Array, nv: jax.Array,
                    rows: Sequence[int]) -> None:
        """Write span-aligned KV ``(n, span, K, hd)`` into ``rows``' pages
        of layer ``li`` (admission: the whole row is overwritten, same
        invariant as ``kvcache.insert_prefill_rows``).  Host-frame pages
        are copied down to the host pools (device->host, accounted)."""
        if self.fully_resident:
            return                      # Mode A: content lives in the
        #                                 engine's contiguous buffers
        pk, pv = self._paged(nk), self._paged(nv)
        dev_f: List[int] = []
        dev_i: List[Tuple[int, int]] = []
        for i, r in enumerate(rows):
            for pp in range(self.pages_per_seq):
                f = int(self.page_map[r, pp])
                assert f >= 0, (r, pp)
                if f < self.device_frames:
                    dev_f.append(f)
                    dev_i.append((i, pp))
                else:
                    h = f - self.device_frames
                    page_k = np.asarray(pk[i, pp])
                    page_v = np.asarray(pv[i, pp])
                    self.host_k[li][h] = page_k
                    self.host_v[li][h] = page_v
                    self.dtoh_bytes += page_k.nbytes + page_v.nbytes
        if dev_f:
            idx = jnp.asarray(dev_f)
            sel = jnp.asarray(dev_i)
            self.pool_k[li] = self.pool_k[li].at[idx].set(
                pk[sel[:, 0], sel[:, 1]]
            )
            self.pool_v[li] = self.pool_v[li].at[idx].set(
                pv[sel[:, 0], sel[:, 1]]
            )
        self._epoch[li] += 1

    def write_host_slot(self, li: int, host_frame: int, offset: int,
                        k_new: np.ndarray, v_new: np.ndarray) -> None:
        """Single-slot decode write into a host frame (the engine calls
        this for rows whose written page lives host-side)."""
        self.host_k[li][host_frame, offset] = k_new
        self.host_v[li][host_frame, offset] = v_new
        self.dtoh_bytes += k_new.nbytes + v_new.nbytes
        self._epoch[li] += 1

    def read_row(self, li: int, row: int, n: int) -> Tuple[np.ndarray,
                                                           np.ndarray]:
        """Gather the first ``n`` token slots of ``row``'s layer-``li`` KV
        as numpy (prefix capture / host-path assembly)."""
        pt = self.page_tokens
        K, hd = self.cfg.num_kv_heads, self.cfg.head_dim
        out_k = np.zeros((self.pages_per_seq * pt, K, hd), self._dtype)
        out_v = np.zeros_like(out_k)
        for pp in range(-(-n // pt)):
            f = int(self.page_map[row, pp])
            if f < 0:
                continue
            if f < self.device_frames:
                k = np.asarray(self.pool_k[li][f])
                v = np.asarray(self.pool_v[li][f])
                self.dtoh_bytes += k.nbytes + v.nbytes
            else:
                h = f - self.device_frames
                k, v = self.host_k[li][h], self.host_v[li][h]
            out_k[pp * pt:(pp + 1) * pt] = k
            out_v[pp * pt:(pp + 1) * pt] = v
        return out_k[:n], out_v[:n]

    # -- decode-time gather plumbing (Mode B) ----------------------------
    def gather_indices(self, rows: Sequence[int]) -> np.ndarray:
        """Frame ids remapped for the paged decode module's gather over
        ``concat([device pool (P+1 incl. null), streamed host frames (H)])``:
        device frame f -> f; host frame h -> P+1+h; unallocated -> the null
        frame P (dead rows gather inert values their masks discard)."""
        P = self.device_frames
        out = np.empty((len(rows), self.pages_per_seq), np.int32)
        for i, r in enumerate(rows):
            for pp in range(self.pages_per_seq):
                f = int(self.page_map[r, pp])
                if f < 0:
                    out[i, pp] = P                      # null sink
                elif f < P:
                    out[i, pp] = f
                else:
                    out[i, pp] = P + 1 + (f - P)
        return out

    def write_targets(self, rows: Sequence[int],
                      wpage: np.ndarray) -> Tuple[np.ndarray, List]:
        """Per-row scatter targets for the decode write: the device pool
        frame (the null frame for host/unallocated pages), plus the list of
        ``(i, host_frame)`` pairs the engine must mirror host-side."""
        P = self.device_frames
        wframe = np.full(len(rows), P, np.int32)
        host_writes: List[Tuple[int, int]] = []
        for i, r in enumerate(rows):
            f = int(self.page_map[r, int(wpage[i])])
            if 0 <= f < P:
                wframe[i] = f
            elif f >= P:
                host_writes.append((i, f - P))
        return wframe, host_writes

    def _fetch_layer(self, li: int):
        """StreamWindow fetch closure: the async htod copy of layer
        ``li``'s ENTIRE host pool (fixed shape (H, pt, K, hd) — stable
        trace keys for the paged decode module), stamped with the layer
        epoch so a stale prefetch is detected at acquire."""
        k = jax.device_put(self.host_k[li])
        v = jax.device_put(self.host_v[li])
        nbytes = self.host_k[li].nbytes + self.host_v[li].nbytes
        return (self._epoch[li], k, v), nbytes

    def prefetch(self, li: int) -> None:
        """Stage layer ``li``'s host-pool transfer a layer ahead (issued
        by the engine before the previous layer's FFN launch, like weight
        prefetch).  No-op in Mode A or for non-attention layers."""
        if self._window is None or not self.cc.prefetch:
            return
        li = li % max(1, self.n_layers)
        if li not in self._epoch:
            return
        self._window.prefetch(li)

    def acquire(self, li: int) -> Tuple[jax.Array, jax.Array]:
        """Layer ``li``'s host frames on device ``(H, pt, K, hd)`` x2,
        consuming the in-flight prefetch; a prefetch made stale by an
        admission/eviction between ticks is discarded and re-fetched on
        demand (epoch check)."""
        assert self._window is not None
        epoch, k, v = self._window.acquire(li)
        if epoch != self._epoch[li]:
            with sanitizer.allowed("stream-window"):
                (epoch, k, v), nbytes = self._fetch_layer(li)
            self._window.htod_bytes += nbytes
            self._window.demand += 1
            jax.block_until_ready((k, v))
        return k, v

    # -- memory-pressure degradation -------------------------------------
    def demote_device_frames(self, limit: int) -> int:
        """Move up to ``limit`` live DEVICE frames to free host frames
        (stage 2 of the admission degradation ladder: relieve device-pool
        pressure instead of raising).  Deterministic victim order —
        highest batch row, highest page first (the coldest end of the
        admission order).  Mode A has no host tier, so this is a no-op
        there; returns the number of frames actually moved.

        Placement-only: Mode B math is independent of which tier a page
        lives in (the gather reassembles either), so demotion never
        changes tokens — only where the bytes sit."""
        if self._window is None or limit <= 0:
            return 0
        moved = 0
        for r in reversed(range(self.batch)):
            for pp in reversed(range(self.pages_per_seq)):
                if moved >= limit or not self._free_host:
                    break
                f = int(self.page_map[r, pp])
                if not (0 <= f < self.device_frames):
                    continue
                h = self._free_host.pop()
                with sanitizer.allowed("paged-host-writeback"):
                    for li in self.attn_layers:
                        k = np.asarray(self.pool_k[li][f])
                        v = np.asarray(self.pool_v[li][f])
                        self.host_k[li][h] = k
                        self.host_v[li][h] = v
                        self.dtoh_bytes += k.nbytes + v.nbytes
                self.page_map[r, pp] = self.device_frames + h
                self._free_dev.append(f)
                moved += 1
            if moved >= limit or not self._free_host:
                break
        if moved:
            faults.note("recovered:page-demotion", moved)
            self._bump_all()
        return moved

    # -- accounting ------------------------------------------------------
    def take_counters(self) -> Tuple[int, int, float]:
        """Drain (htod_bytes, dtoh_bytes, stream_wait_s) since last call."""
        htod, wait = (self._window.take_counters()
                      if self._window is not None else (0, 0.0))
        dtoh = self.dtoh_bytes
        self.dtoh_bytes = 0
        return htod, dtoh, wait

    def take_fault_counters(self) -> Tuple[int, int]:
        """Drain (transfer retries, watchdog timeouts) of the page stream
        window since the last call."""
        return (self._window.take_fault_counters()
                if self._window is not None else (0, 0))


class PrefixStore:
    """LRU prefix cache over page-aligned prompt prefixes.

    Keys are the EXACT prefix token bytes (no hash collisions by
    construction) at the largest page multiple strictly below the prompt
    length — at least one suffix token always remains, so a hit still
    produces the request's first-token logits through the engine's
    suffix prefill.  Values are per-attention-layer ``(k, v)`` numpy
    arrays of the prefix span; admission copies them into the hit row's
    page/cache rows instead of recomputing prefill (KV at position p
    depends only on tokens <= p, so copied rows are exactly what the full
    prefill would write).

    Restricted to all-attention models without a sliding window: SSM state
    and ring-aligned windows make a stored prefix non-transplantable.
    """

    def __init__(self, page_tokens: int, entries: int = 64) -> None:
        assert page_tokens > 0
        self.page_tokens = page_tokens
        self.entries = max(1, entries)
        self._store: "OrderedDict[bytes, List]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def supported(cfg: ModelConfig) -> bool:
        return cfg.sliding_window == 0 and all(
            cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers)
        )

    def key(self, prompt: np.ndarray) -> Optional[Tuple[bytes, int]]:
        """(key bytes, prefix span) for ``prompt``, or None when no full
        page fits strictly inside it."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        pspan = ((len(prompt) - 1) // self.page_tokens) * self.page_tokens
        if pspan <= 0:
            return None
        return prompt[:pspan].tobytes(), pspan

    def get(self, key: bytes) -> Optional[List]:
        kvs = self._store.get(key)
        if kvs is None:
            self.misses += 1
            return None
        self._store.move_to_end(key)
        self.hits += 1
        return kvs

    def put(self, key: bytes, kvs: List) -> None:
        if key in self._store:
            self._store.move_to_end(key)
            return
        self._store[key] = kvs
        while len(self._store) > self.entries:
            self._store.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
