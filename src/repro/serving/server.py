"""Request-lifecycle serving: one step-driven core under both schedulers.

``Server`` is the serving facade over ``ModuleBatchingEngine`` +
``ParamStore``: requests are submitted (``submit(Request) ->
RequestHandle``), become admissible at their ``arrival_s`` offset on a
virtual clock keyed off wall time, and are driven by ``step()`` — ONE
module-batched decode tick that admits due arrivals, decodes every live
slot, samples each slot under its own ``SamplingParams``, and
evicts/recycles finished sequences.  ``run()`` loops ``step()`` (sleeping
through idle gaps until the next arrival) and returns the ``ServeReport``.

When the engine's fused decode path is eligible and no admission or
eviction can fall due mid-chunk, ``step()`` batches up to
``decode_chunk`` ticks into ONE fused device dispatch
(``engine.decode_chunk``) — clamped to the shortest remaining decode so
every finish event still lands at a chunk boundary; tokens, timestamps
and the waste accounting are tick-identical to per-tick stepping (see
``_chunk_T``).

The two scheduler modes are thin *admission policies* over that single
core — the prefill/decode/EOS/latency bookkeeping lives once:

* ``static`` — the paper's offline protocol (§5.1): requests are admitted
  in waves, a new wave only when the previous one has fully drained; every
  wave slot keeps stepping until the wave's slowest member finishes
  (early finishers are counted in ``wasted_slot_steps``), and each wave's
  raw token matrix is recorded as a ``BatchResult``.
* ``continuous`` — in-flight batching (vLLM-style): a finished sequence's
  slot, KV rows and SSM state are evicted immediately and the freed slot
  is recycled by prefilling the next due request into it; with
  ``ServeConfig.hw`` set, admission is additionally gated by the Eq. 2
  host KV budget (the queue head waits, FIFO, counted in
  ``admission_deferrals``).

Both modes produce identical tokens per request when the plan's expert
capacity ``b_e`` admits every routed token (capacity drops depend on batch
composition, which the modes schedule differently), and the sampling
determinism contract (see ``serving.sampling``) makes that hold for
seeded sampled requests too.

Per-request latency metrics are measured on the virtual clock:
``queue_wait_s`` (arrival -> admission), ``ttft_s`` (arrival -> first
token, which the admission prefill produces), and ``tpot_s`` (mean
per-token latency after the first).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.analysis import runtime as sanitizer
from repro.analysis.markers import hot_path
from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag_builder import Plan
from repro.core.hardware import HardwareProfile
from repro.serving.sampling import BatchSampler, SamplingParams
from repro.serving.weights import ParamStore


# ---------------------------------------------------------------------------
# Requests, configs, results
# ---------------------------------------------------------------------------
@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    decode_len: int
    arrival_s: float = 0.0        # admissible-from offset on the virtual clock
    sampling: Optional[SamplingParams] = None   # None = greedy


@dataclass(frozen=True)
class ServeConfig:
    """Scheduling-side knobs, frozen (was: the ``serve_dataset`` kwarg
    sprawl).  ``decode_len`` is the fallback for requests whose own field
    is zero/None; ``hw`` enables Eq. 2 memory-gated admission in the
    continuous scheduler.

    KV-cache knobs (the ``serving.cache`` tier): ``kv_page_tokens > 0``
    pages the cache; ``device_kv_gb`` caps the device page pool (the
    remainder streams from the host tier); ``prefix_cache`` admits repeated
    prompt prefixes by copying cached page rows instead of recomputing
    prefill (attention-only models without a sliding window).

    ``from_plan`` builds a config sized by the planner up front —
    ``max_batch``/``max_seq`` come from ``planner.search_decode`` instead
    of the first step's submitted queue.
    """

    scheduler: str = "static"
    decode_len: int = 32
    max_seq: Optional[int] = None
    max_prompt_len: Optional[int] = None
    pad_id: int = 0
    eos_id: Optional[int] = None
    expert_path: str = "grouped"
    grouped_prefill: bool = True
    hw: Optional[HardwareProfile] = None
    decode_chunk: Optional[int] = None   # fused chunk T cap (None = plan's);
    #                                      1 disables multi-token stepping
    kv_page_tokens: int = 0              # page the KV cache (0 = contiguous)
    device_kv_gb: Optional[float] = None  # device page-pool cap (None = all)
    prefix_cache: bool = False           # reuse shared prompt prefixes
    max_batch: Optional[int] = None      # engine slots (None = sized at the
    #                                      first step from the submitted queue)
    plan: Optional[Plan] = None          # planner-produced Plan (from_plan);
    #                                      used when Server gets plan=None
    replan_skew: Optional[float] = None  # online capacity re-plan: re-derive
    #   b_e from the measured expert-load histogram whenever the hottest
    #   expert's share drifts by more than this (absolute share delta);
    #   None disables re-planning
    replan_drop_target: float = 0.01     # expected drop-rate bound the
    #                                      re-planned capacity is sized for
    sctx: Optional[object] = None        # sharding.specs.ShardCtx with a mesh
    #   + model axis: the engine runs the MoE stage as collective dispatch
    #   (repro.distributed.ep_engine); None = single-device (byte-identical
    #   to the pre-mesh paths)
    ep_chunks: int = 1                   # pipeline chunks the a2a MoE stage
    #   splits the accumulated batch into (chunk k+1's all-to-all overlaps
    #   chunk k's expert FFN); 1 = serial dispatch
    faults: Optional[object] = None      # fault-injection schedule: a
    #   repro.faults FaultPlan / FaultSpec / spec string ("seed=0,
    #   transfer=0.1,..."); None = unarmed (the ambient REPRO_FAULTS plan,
    #   if any, still applies).  Armed around every step, so the stream /
    #   page / preemption seams consult it; recovery is counted in the
    #   report (transfer_retries, preemptions, ...)

    def __post_init__(self) -> None:
        assert self.scheduler in ("static", "continuous"), self.scheduler
        assert self.expert_path in ("grouped", "loop"), self.expert_path
        assert self.kv_page_tokens >= 0, self.kv_page_tokens
        if self.prefix_cache:
            assert self.kv_page_tokens > 0, (
                "prefix_cache requires paging (kv_page_tokens > 0)"
            )
        if self.max_batch is not None:
            assert self.max_batch >= 1, self.max_batch

    @classmethod
    def from_plan(
        cls,
        cfg: ModelConfig,
        hw: HardwareProfile,
        ctx: int = 512,
        scheduler: str = "continuous",
        B: Optional[int] = None,
        **overrides,
    ) -> "ServeConfig":
        """Size the serving config from the planner: runs
        ``planner.search_decode(cfg, hw, ctx)`` and pins ``max_batch`` to
        the plan's B, ``max_seq`` to ``ctx``, and ``hw`` for Eq. 2 gated
        admission — so the server allocates its engine up front instead of
        from whatever happens to be queued at the first step.  ``B`` caps
        the searched batch (Eq. 2 makes the host limit of a smoke-scale
        config astronomical — cap it to what the workload and this
        machine's memory actually support).  Keyword overrides win over
        the derived fields; the Plan rides along in ``.plan`` (pass
        ``Server(cfg, params, plan=None, serve=...)``)."""
        from repro.core.planner import search_decode

        plan = search_decode(cfg, hw, ctx, B=B, scheduler=scheduler,
                             decode_len=overrides.get("decode_len")).plan
        kw = dict(scheduler=scheduler, max_seq=ctx, max_batch=plan.B,
                  hw=hw, plan=plan)
        kw.update(overrides)
        return cls(**kw)


@dataclass(frozen=True)
class StreamConfig:
    """Weight-residency knobs for the ``ParamStore`` the server builds
    (ignored when a pre-built ``store`` is passed)."""

    stream_weights: bool = False
    resident_bytes: Optional[float] = None
    prefetch: bool = True
    predict_topk: Optional[int] = None   # per-expert predictive streaming
    #   (None = follow the plan's predict_topk; 0 forces whole-stack)
    lru_bytes: Optional[float] = None    # hot-expert device LRU budget
    #   (None = the residency plan's spare bytes)


@dataclass
class BatchResult:
    tokens: np.ndarray            # (B, decode_len) raw batch tokens (static)
    prefill_s: float
    decode_s: float
    expert_tokens_dropped: int = 0   # routed copies over the b_e capacity


@dataclass
class RequestResult:
    index: int                    # position in the input request list
    tokens: np.ndarray            # (n,) generated tokens (<= decode_len; EOS cut)
    latency_s: float              # admission -> last token (incl. its prefill)
    decode_steps: int             # decode steps while this request was live
    arrival_s: float = 0.0        # admissible-from offset (virtual clock)
    queue_wait_s: float = 0.0     # arrival -> admission
    ttft_s: float = 0.0           # arrival -> first token
    tpot_s: float = 0.0           # mean per-token latency after the first


@dataclass
class ServeReport:
    results: List[BatchResult] = field(default_factory=list)
    request_results: List[RequestResult] = field(default_factory=list)
    scheduler: str = "static"
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_slot_steps: int = 0    # decode steps x batch slots executed
    wasted_slot_steps: int = 0    # slot-steps spent on finished/empty slots
    weight_htod_bytes: int = 0    # streamed weight bytes copied host->device
    prefetch_wait_s: float = 0.0  # stall waiting on weight transfers
    admission_deferrals: int = 0  # admissions blocked by the Eq. 2 KV budget
    kv_htod_bytes: int = 0        # streamed KV-page bytes copied host->device
    kv_dtoh_bytes: int = 0        # KV bytes spilled device->host
    prefix_hits: int = 0          # admissions served from the prefix cache
    prefix_misses: int = 0        # eligible admissions that prefilled cold
    prefill_tokens: int = 0       # token-positions actually computed in prefill
    #   (full prompts on a miss, suffix only on a prefix hit — the gap vs
    #   sum(len(prompt)) is the prefill work the prefix cache skipped)
    _expert_dropped: int = 0      # drops counted outside BatchResults
    # predictive per-expert streaming + imbalance accounting (grouped path)
    expert_dropped_by_layer: Optional[np.ndarray] = None  # (n_moe,) drops
    expert_load: Optional[np.ndarray] = None  # (n_moe, E) routed-copy hist
    expert_pred_hits: int = 0     # expert was staged by the l+1 prediction
    expert_pred_misses: int = 0   # demand-fetched (mispredicted/cold) experts
    expert_lru_hits: int = 0      # served from the hot-expert device LRU
    capacity_replans: int = 0     # online b_e re-plans on measured skew drift
    a2a_bytes: int = 0            # interconnect bytes the mesh MoE stage
    #                               exchanged (a2a dispatch + return)
    collective_dispatches: int = 0  # mesh MoE stage launches (a2a/psum)
    # fault-recovery accounting (repro.faults): every recovery is counted
    # so fault handling is observable, never silent
    transfer_retries: int = 0     # transient stream fetches recovered by retry
    transfer_timeouts: int = 0    # watchdog-expired waits recovered by re-fetch
    preemptions: int = 0          # running requests evicted to host checkpoints
    resumes: int = 0              # checkpoints re-admitted (zero prefill relaunch)
    degrade_deferrals: int = 0    # admissions deferred under page-alloc pressure
    page_demotions: int = 0       # device page frames demoted to the host tier
    chunk_shrinks: int = 0        # decode-chunk cap halvings under pressure
    failovers: int = 0            # dead replicas failed over (ReplicaServer)
    requeued_requests: int = 0    # requests requeued onto surviving replicas

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def a2a_gb(self) -> float:
        """Expert-parallel all-to-all traffic in GB (0 off-mesh)."""
        return self.a2a_bytes / 1e9

    @property
    def htod_gb(self) -> float:
        """Streamed weight traffic in GB (0 when everything is resident)."""
        return self.weight_htod_bytes / 1e9

    @property
    def kv_htod_gb(self) -> float:
        """Streamed KV-page traffic in GB (0 without a host tier)."""
        return self.kv_htod_bytes / 1e9

    @property
    def kv_dtoh_gb(self) -> float:
        return self.kv_dtoh_bytes / 1e9

    @property
    def prefix_hit_rate(self) -> float:
        n = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / n if n else 0.0

    @property
    def decode_tokens(self) -> int:
        """Valid generated tokens (per-request decode_len / EOS honored)."""
        return sum(r.tokens.size for r in self.request_results)

    @property
    def expert_tokens_dropped(self) -> int:
        return self._expert_dropped + sum(
            r.expert_tokens_dropped for r in self.results
        )

    @property
    def routing_skew(self) -> float:
        """Hottest expert's measured share of routed copies (aggregated
        over MoE layers), as a multiple of the balanced share ``1/E`` —
        1.0 is perfectly balanced, E is fully collapsed routing.  0.0
        when no routed copies were measured (dense model / loop path)."""
        if self.expert_load is None:
            return 0.0
        per_expert = self.expert_load.sum(axis=0)
        total = per_expert.sum()
        if total <= 0:
            return 0.0
        return float(per_expert.max() / total * per_expert.size)

    @property
    def pred_hit_rate(self) -> float:
        """Fraction of decode-stage expert fetches the l+1 prediction (or
        the LRU) had already paid for — the htod latency actually hidden."""
        n = self.expert_pred_hits + self.expert_pred_misses
        return self.expert_pred_hits / n if n else 0.0

    @property
    def lru_hit_rate(self) -> float:
        """Fraction of decode-stage expert uses served from the hot-expert
        LRU (no copy at all), over all uses."""
        n = (self.expert_pred_hits + self.expert_pred_misses
             + self.expert_lru_hits)
        return self.expert_lru_hits / n if n else 0.0

    @property
    def decode_throughput(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Fraction of executed decode slot-steps that produced live tokens."""
        if self.decode_slot_steps == 0:
            return 1.0
        return 1.0 - self.wasted_slot_steps / self.decode_slot_steps

    @property
    def mean_latency_s(self) -> float:
        rr = self.request_results
        return sum(r.latency_s for r in rr) / len(rr) if rr else 0.0

    @property
    def mean_queue_wait_s(self) -> float:
        rr = self.request_results
        return sum(r.queue_wait_s for r in rr) / len(rr) if rr else 0.0

    @property
    def mean_ttft_s(self) -> float:
        rr = self.request_results
        return sum(r.ttft_s for r in rr) / len(rr) if rr else 0.0

    @property
    def mean_tpot_s(self) -> float:
        rr = self.request_results
        return sum(r.tpot_s for r in rr) / len(rr) if rr else 0.0

    def ttft_percentile(self, q: float) -> float:
        rr = self.request_results
        return float(np.percentile([r.ttft_s for r in rr], q)) if rr else 0.0

    def tpot_percentile(self, q: float) -> float:
        rr = self.request_results
        return float(np.percentile([r.tpot_s for r in rr], q)) if rr else 0.0


def pad_requests(requests, pad_id: int = 0,
                 max_prompt_len: Optional[int] = None):
    """Right-pad a request chunk to its longest prompt.

    Prompts longer than ``max_prompt_len`` (when given) are truncated to it
    first.  Returns ``(tokens (B, S), lengths (B,))`` — the lengths are what
    make the padding exact downstream (prefill masks pads and gathers each
    sequence's logits at its true last token).
    """
    prompts = []
    for r in requests:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        if max_prompt_len is not None:
            p = p[:max_prompt_len]
        prompts.append(p)
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    S = max(1, int(lengths.max())) if prompts else 1
    out = np.full((len(requests), S), pad_id, np.int32)
    for i, p in enumerate(prompts):
        out[i, : len(p)] = p
    return out, lengths


# ---------------------------------------------------------------------------
# Request lifecycle
# ---------------------------------------------------------------------------
class RequestHandle:
    """A submitted request's live view: status, the token stream as it is
    produced, and the timing marks the metrics derive from.

    Streaming: pass ``on_token=`` to ``Server.submit`` for a synchronous
    per-token callback, or iterate ``handle.stream()`` — the iterator
    drives ``Server.step()`` until the next token (or the end of the
    stream) is available.
    """

    def __init__(self, server: "Server", index: int, request: Request,
                 prompt: np.ndarray, decode_len: int,
                 on_token: Optional[Callable] = None) -> None:
        self._server = server
        self.index = index
        self.request = request
        self.prompt = prompt              # truncated to max_prompt_len
        self.decode_len = decode_len      # resolved fallback applied
        self.sampling = request.sampling
        self.arrival_s = float(request.arrival_s or 0.0)
        self.on_token = on_token
        # queued -> running -> finished, with running <-> preempted when
        # the server evicts the request to a host checkpoint and resumes it
        self.status = "queued"
        self.tokens: List[int] = []
        self.admit_s = float("nan")
        self.first_token_s = float("nan")
        self.finish_s = float("nan")
        self.decode_steps = 0

    @property
    def finished(self) -> bool:
        return self.status == "finished"

    def _emit(self, token: int) -> None:
        self.tokens.append(token)
        if self.on_token is not None:
            self.on_token(self, token)

    def stream(self) -> Iterator[int]:
        """Yield tokens as they are produced, driving the server forward."""
        sent = 0
        while True:
            while sent < len(self.tokens):
                yield self.tokens[sent]
                sent += 1
            if self.finished:
                return
            self._server._wait_for_arrival()
            self._server.step()

    def result(self) -> RequestResult:
        assert self.finished, f"request {self.index} is {self.status}"
        n = len(self.tokens)
        return RequestResult(
            index=self.index,
            tokens=np.asarray(self.tokens, np.int32),
            latency_s=self.finish_s - self.admit_s,
            decode_steps=self.decode_steps,
            arrival_s=self.arrival_s,
            queue_wait_s=self.admit_s - self.arrival_s,
            ttft_s=self.first_token_s - self.arrival_s,
            tpot_s=(self.finish_s - self.first_token_s) / max(1, n - 1),
        )


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------
class Server:
    """Facade over ``ModuleBatchingEngine`` + ``ParamStore``: submit
    requests, drive them with ``step()`` / ``run()``, read the report.

    The engine (and its ``plan.B``-slot cache) is built lazily at the first
    step, sized ``min(plan.B, submitted requests)`` — submit the initial
    workload before stepping so the batch is not over-allocated.  Requests
    submitted later join the queue and reuse the existing slots; their
    prompt+decode extent must fit the realized ``max_seq``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        plan: Optional[Plan] = None,
        serve: ServeConfig = ServeConfig(),
        stream: StreamConfig = StreamConfig(),
        store: Optional[ParamStore] = None,
    ) -> None:
        if plan is None:
            plan = serve.plan
        assert plan is not None, (
            "pass a Plan, or a ServeConfig built by ServeConfig.from_plan"
        )
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.serve = serve
        self.stream = stream
        self.report = ServeReport(scheduler=serve.scheduler)
        self._store = store
        # prefix cache (needs paging; attention-only, no sliding window —
        # SSM state / ring alignment make prefixes non-transplantable)
        self._prefix = None
        if serve.prefix_cache:
            from repro.serving.cache import PrefixStore

            if PrefixStore.supported(cfg):
                self._prefix = PrefixStore(serve.kv_page_tokens)
        self._engine = None               # ModuleBatchingEngine, built lazily
        self._sampler: Optional[BatchSampler] = None
        self._handles: List[RequestHandle] = []
        self._pending: List = []          # heap of (arrival_s, index, handle)
        self._t0: Optional[float] = None
        self._max_seq: Optional[int] = serve.max_seq
        # engine-stat totals already drained into the report
        self._seen = {"drop": 0, "htod": 0, "wait": 0.0, "kvh": 0, "kvd": 0,
                      "ph": 0, "pm": 0, "lh": 0, "a2a": 0, "cd": 0,
                      "retr": 0, "tmo": 0}
        # online capacity re-plan (replan_skew): the hottest expert's share
        # at the last (re-)plan; None until the first measurement
        self._replan_share: Optional[float] = None
        self._replan_ticks = 0
        # Eq. 2 admission budget (continuous): every in-flight sequence's
        # offloaded KV/state at its FULL prompt+decode extent must fit
        # m_c - S_Model, so a sequence can never outgrow the host mid-decode
        self._kv_budget = (
            None if serve.hw is None or serve.scheduler != "continuous"
            else _host_kv_budget(cfg, serve.hw)
        )
        self._kv_need: Dict[int, float] = {}
        self._live_kv = 0.0
        # slot state (allocated with the engine)
        self._b = 0
        self._free: deque = deque()
        self._slot_handle: List[Optional[RequestHandle]] = []
        self._cur: Optional[np.ndarray] = None
        self._pos: Optional[np.ndarray] = None
        self._wave: Optional[Dict] = None     # static policy's in-flight wave
        # fault tolerance (repro.faults): the resolved plan is armed around
        # every step; preempted requests wait in _ckpts (FIFO) for a slot
        self._faults = faults.resolve(serve.faults)
        self._ckpts: deque = deque()          # host-side request checkpoints
        self._ticks = 0                       # decode ticks run (virtual clock)
        self._preempt_due_at: Optional[int] = None   # next injected preempt
        self._pressure = 0                    # consecutive page-OOM events
        self._shrink_cap: Optional[int] = None   # degraded decode-chunk cap
        self._shrink_ticks = 0                # steps the shrink stays active

    # -- lifecycle: submit -------------------------------------------------
    def submit(self, request: Request,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Queue a request; it becomes admissible at ``request.arrival_s``.

        Raises ``ValueError`` immediately for a request that could never be
        served: prompt+decode beyond ``max_seq``, or (continuous with
        ``hw``) KV/state that can never fit the Eq. 2 host budget.

        Error-path invariant (validate-then-mutate): every rejection above
        raises BEFORE any server state is touched — no handle is created,
        nothing enters the arrival heap, no ``_kv_need`` entry is written
        — so a rejected submit followed by valid submits drains
        identically to never having submitted it.
        """
        serve = self.serve
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if serve.max_prompt_len is not None:
            prompt = prompt[: serve.max_prompt_len]
        dec = max(1, int(request.decode_len or serve.decode_len))
        i = len(self._handles)
        arrival = float(request.arrival_s or 0.0)
        if not np.isfinite(arrival) or arrival < 0:
            # a NaN head would never compare due and the server would spin
            raise ValueError(
                f"request {i}: arrival_s must be finite and >= 0, "
                f"got {request.arrival_s!r}"
            )
        limit = self._max_seq
        if limit is not None and len(prompt) + dec > limit:
            raise ValueError(
                f"request {i}: prompt length {len(prompt)} + decode_len "
                f"{dec} exceeds the engine's max_seq={limit}; pass "
                f"max_prompt_len to truncate long prompts"
            )
        if self._kv_budget is not None:
            # frame-granular admission: the paged cache allocates whole
            # pages, so the charge is the page-rounded extent
            need = W.kv_bytes_per_seq(
                self.cfg, len(prompt) + dec,
                page_tokens=self.serve.kv_page_tokens,
            )
            if need > self._kv_budget:
                raise ValueError(
                    f"request {i}: KV/state bytes {need:.3e} can never fit "
                    f"the Eq. 2 host budget {self._kv_budget:.3e} (host_mem "
                    f"- model); truncate with max_prompt_len or shrink "
                    f"decode_len"
                )
        # -- all checks passed: mutate ------------------------------------
        if self._kv_budget is not None:
            self._kv_need[i] = need
        h = RequestHandle(self, i, request, prompt, dec, on_token)
        self._handles.append(h)
        heapq.heappush(self._pending, (h.arrival_s, i, h))
        return h

    # -- clock -------------------------------------------------------------
    def _now(self) -> float:
        """Virtual clock: seconds since the first step (arrivals are
        offsets on this clock)."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    @property
    def next_arrival_s(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    def _wait_for_arrival(self) -> None:
        """Sleep until the next queued arrival when nothing is live."""
        if self._any_live() or not self._pending:
            return
        dt = self.next_arrival_s - self._now()
        if dt > 0:
            time.sleep(min(dt, 0.05))

    # -- engine ------------------------------------------------------------
    def _ensure_engine(self) -> None:
        if self._engine is not None:
            return
        # imported here: core.engine itself imports serving.weights, so a
        # top-level import would cycle through the serving package __init__
        from repro.core.engine import ModuleBatchingEngine

        if self._store is None:
            st = self.stream
            self._store = ParamStore.build(
                self.cfg, self.params, self.plan,
                stream_weights=st.stream_weights,
                resident_bytes=st.resident_bytes, prefetch=st.prefetch,
                predict_topk=st.predict_topk, lru_bytes=st.lru_bytes,
            )
        if self.serve.max_batch is not None:
            # planner-sized up front (ServeConfig.from_plan): the engine
            # batch no longer depends on what was queued at the first step
            self._b = max(1, min(self.plan.B, int(self.serve.max_batch)))
        else:
            self._b = max(1, min(self.plan.B, len(self._handles) or 1))
        if self._max_seq is None:
            self._max_seq = max(
                len(h.prompt) + h.decode_len for h in self._handles
            )
        self._engine = ModuleBatchingEngine(
            self.cfg, self.params, self.plan, max_seq=self._max_seq,
            expert_path=self.serve.expert_path,
            grouped_prefill=self.serve.grouped_prefill, store=self._store,
            cache_config=self._cache_config(),
            sctx=self.serve.sctx, ep_chunks=self.serve.ep_chunks,
        )
        self._engine.init_cache(self._b)
        self._sampler = BatchSampler(self._b)
        self._free = deque(range(self._b))
        self._slot_handle = [None] * self._b
        self._cur = np.zeros(self._b, np.int32)
        self._pos = np.zeros(self._b, np.int64)

    def _cache_config(self):
        """The ``CacheConfig`` realized from the serve knobs (None when
        paging is off — the engine keeps its contiguous buffers)."""
        if self.serve.kv_page_tokens <= 0:
            return None
        from repro.serving.cache import CacheConfig

        budget = (None if self.serve.device_kv_gb is None
                  else float(self.serve.device_kv_gb) * 1e9)
        return CacheConfig(
            page_tokens=self.serve.kv_page_tokens,
            device_pool_bytes=budget,
            prefix_cache=self._prefix is not None,
        )

    def _drain_engine_stats(self) -> int:
        """Fold the engine's cumulative counters into the report (deltas
        since the last drain); returns the expert-drop delta."""
        if self._engine is None:
            return 0
        st = self._engine.sync_stats()
        d_drop = st.expert_tokens_dropped - self._seen["drop"]
        self.report.weight_htod_bytes += st.weight_htod_bytes - self._seen["htod"]
        self.report.prefetch_wait_s += st.prefetch_wait_s - self._seen["wait"]
        self.report.kv_htod_bytes += st.kv_htod_bytes - self._seen["kvh"]
        self.report.kv_dtoh_bytes += st.kv_dtoh_bytes - self._seen["kvd"]
        self.report.expert_pred_hits += st.expert_pred_hits - self._seen["ph"]
        self.report.expert_pred_misses += (st.expert_pred_misses
                                           - self._seen["pm"])
        self.report.expert_lru_hits += st.expert_lru_hits - self._seen["lh"]
        self.report.a2a_bytes += st.a2a_bytes - self._seen["a2a"]
        self.report.collective_dispatches += (st.collective_dispatches
                                              - self._seen["cd"])
        self.report.transfer_retries += st.transfer_retries - self._seen["retr"]
        self.report.transfer_timeouts += (st.transfer_timeouts
                                          - self._seen["tmo"])
        # cumulative engine totals — one engine per server, so the report's
        # arrays are simply the latest snapshot (copies: the engine keeps
        # accumulating into its own buffers)
        if st.expert_tokens_dropped_by_layer is not None:
            self.report.expert_dropped_by_layer = (
                st.expert_tokens_dropped_by_layer.copy()
            )
            self.report.expert_load = st.expert_load.copy()
        self._seen = {"drop": st.expert_tokens_dropped,
                      "htod": st.weight_htod_bytes,
                      "wait": st.prefetch_wait_s,
                      "kvh": st.kv_htod_bytes,
                      "kvd": st.kv_dtoh_bytes,
                      "ph": st.expert_pred_hits,
                      "pm": st.expert_pred_misses,
                      "lh": st.expert_lru_hits,
                      "a2a": st.a2a_bytes,
                      "cd": st.collective_dispatches,
                      "retr": st.transfer_retries,
                      "tmo": st.transfer_timeouts}
        return d_drop

    def _maybe_replan(self) -> None:
        """Online imbalance-aware capacity re-plan: when the hottest
        expert's measured share has drifted more than ``replan_skew`` since
        the last (re-)plan, re-derive ``b_e`` from the measured per-expert
        load via ``planner.capacity_for_load`` and push it into the engine
        (``set_expert_capacity`` — the next dispatch retraces once).
        Checked every 8 decode steps to keep the host sync off the
        every-tick path."""
        self._replan_ticks += 1
        if self._replan_ticks % 8:
            return
        self.report._expert_dropped += self._drain_engine_stats()
        if self.report.expert_load is None:
            return
        per_expert = self.report.expert_load.sum(axis=0)
        total = per_expert.sum()
        if total <= 0:
            return
        share = float(per_expert.max() / total)
        if self._replan_share is None:
            self._replan_share = share       # baseline, no re-plan yet
            return
        if abs(share - self._replan_share) <= self.serve.replan_skew:
            return
        from repro.core.planner import capacity_for_load

        b_e = capacity_for_load(
            per_expert, self._b, self.cfg.experts_per_token,
            max_drop_rate=self.serve.replan_drop_target,
        )
        self._engine.set_expert_capacity(b_e)
        self._replan_share = share
        self.report.capacity_replans += 1

    # -- the step-driven core ---------------------------------------------
    def _any_live(self) -> bool:
        return any(h is not None for h in self._slot_handle)

    def has_work(self) -> bool:
        return (self._any_live() or bool(self._pending)
                or bool(self._ckpts))

    def step(self) -> bool:
        """One scheduler tick: admit due arrivals (policy-dependent), run
        one module-batched decode step over every slot, sample each live
        slot under its own ``SamplingParams``, finish/evict/recycle.
        Returns True while work remains (live slots, queued requests, or
        preempted checkpoints); with only future arrivals pending it
        returns True without decoding — ``run()`` sleeps through such
        gaps, manual steppers can watch ``next_arrival_s``.

        The whole tick runs with the server's fault plan armed
        (``ServeConfig.faults``; a pass-through to the ambient
        ``REPRO_FAULTS`` plan when unset), so every stream / page /
        preemption seam underneath consults the same schedule.
        """
        if not self.has_work():
            return False
        self._ensure_engine()
        with faults.armed(self._faults):
            self._maybe_preempt()
            self._admit()
            if self._any_live():
                self._decode_tick(self._chunk_T())
                if self.serve.replan_skew is not None:
                    self._maybe_replan()
        return self.has_work()

    def run(self, until_idle: bool = True) -> ServeReport:
        """Drive ``step()`` to completion and return the report.

        ``until_idle=False`` stops at the first moment nothing is live or
        due (future arrivals are left queued) instead of sleeping for them.
        """
        while self.step():
            if not self._any_live() and self._pending:
                if not until_idle and self.next_arrival_s > self._now():
                    break
                self._wait_for_arrival()
        return self.finalize()

    def finalize(self) -> ServeReport:
        """Drain engine counters and order results; idempotent."""
        self.report._expert_dropped += self._drain_engine_stats()
        if self._prefix is not None:
            self.report.prefix_hits = self._prefix.hits
            self.report.prefix_misses = self._prefix.misses
        self.report.request_results.sort(key=lambda r: r.index)
        return self.report

    # -- admission policies ------------------------------------------------
    def _pop_due(self, now: float) -> Optional[RequestHandle]:
        """Pop the queue head if it has arrived (FIFO in arrival order —
        later requests are never reordered past a waiting head)."""
        if self._pending and self._pending[0][0] <= now:
            return heapq.heappop(self._pending)[2]
        return None

    def _admit(self) -> None:
        if self.serve.scheduler == "static":
            self._admit_static()
        else:
            self._admit_continuous()

    def _admit_static(self) -> None:
        """Admit-in-waves policy: a new wave only once the previous wave has
        fully drained; the wave takes every due request up to B slots."""
        if self._wave is not None:
            return
        now = self._now()
        handles: List[RequestHandle] = []
        while len(handles) < self._b:
            h = self._pop_due(now)
            if h is None:
                break
            # reserve the wave slot's page frames up front: an OOM (real
            # exhaustion or injected) degrades — requeue + demote/shrink —
            # instead of aborting mid-prefill
            try:
                self._engine.reserve_slot_rows([len(handles)])
            except faults.PageAllocOOM as err:
                heapq.heappush(self._pending, (h.arrival_s, h.index, h))
                self._degrade_on_oom(err)
                break
            self._pressure = 0
            handles.append(h)
        if not handles:
            return
        slots = list(range(len(handles)))
        self._wave = {
            "slots": slots, "handles": handles,
            "rows": [[] for _ in slots], "done": [False] * len(slots),
            "ticks": 0, "prefill_s": 0.0, "decode_s": 0.0,
        }
        self._prefill_wave(handles, slots)
        if all(self._wave["done"]):
            self._close_wave()

    def _admit_continuous(self) -> None:
        """Admit/evict policy: prefill due requests into freed slots (one
        batched prefill per admission wave; insta-finishers free their slot
        again, so loop until stable).  With an Eq. 2 budget the queue head
        WAITS while its KV bytes don't fit next to the in-flight
        sequences' (FIFO — later smaller requests are not reordered past
        it).  Preempted checkpoints resume FIRST (they were admitted
        before anything still queued), restoring their KV rows and sampler
        token index with zero prefill relaunches."""
        now = self._now()
        self._resume_checkpoints(now)
        blocked = False
        while (not blocked and self._free and self._pending
               and self._pending[0][0] <= now):
            slots, handles = [], []
            while self._free and self._pending and self._pending[0][0] <= now:
                i = self._pending[0][1]
                if (self._kv_budget is not None
                        and self._live_kv + self._kv_need[i] > self._kv_budget):
                    break              # head waits for an eviction
                h = heapq.heappop(self._pending)[2]
                s = self._free.popleft()
                # page-frame reservation up front: an OOM (real exhaustion
                # or injected) degrades — defer/demote/shrink — instead of
                # aborting mid-prefill; the handle goes back to the head
                try:
                    self._engine.reserve_slot_rows([s])
                except faults.PageAllocOOM as err:
                    self._free.appendleft(s)
                    heapq.heappush(self._pending, (h.arrival_s, h.index, h))
                    self._degrade_on_oom(err)
                    blocked = True
                    break
                self._pressure = 0
                slots.append(s)
                handles.append(h)
                if self._kv_budget is not None:
                    self._live_kv += self._kv_need[i]
            if not handles:
                break                  # nothing admissible this attempt
            self._prefill_wave(handles, slots)
        # counted ONCE per admission attempt: the head is due but leaving
        # this attempt memory-blocked despite a free slot
        if (self._kv_budget is not None and self._free and self._pending
                and self._pending[0][0] <= now
                and self._live_kv + self._kv_need[self._pending[0][1]]
                > self._kv_budget):
            self.report.admission_deferrals += 1

    # -- fault tolerance: preempt / checkpoint / resume --------------------
    def preempt(self, handle: RequestHandle) -> bool:
        """Evict a RUNNING request to a host-side checkpoint (KV/state
        rows + current token + position; the sampler key/step restore from
        the handle itself).  The slot, page frames and sampler slot are
        freed for other requests; the checkpoint re-admits prefix-style
        (``_resume_checkpoints``) with ZERO prefill relaunches, and —
        because sampling is keyed on ``(seed, token_index)`` — the resumed
        stream is bit-identical to an unpreempted run.

        Continuous scheduler only (a static wave drains in place — its
        slots cannot be recycled mid-wave).  Returns False when the handle
        is not currently running."""
        assert self.serve.scheduler == "continuous", (
            "preemption is a continuous-scheduler policy"
        )
        if handle.status != "running":
            return False
        self._preempt_slot(self._slot_handle.index(handle), self._now())
        return True

    def _preempt_slot(self, s: int, now: float) -> None:
        h = self._slot_handle[s]
        ckpt = {
            "handle": h,
            "state": self._engine.checkpoint_slot(s),
            "cur": int(self._cur[s]),
            "pos": int(self._pos[s]),
        }
        h.status = "preempted"
        if self._kv_budget is not None:
            self._live_kv -= self._kv_need[h.index]
        self._slot_handle[s] = None
        self._sampler.clear_slot(s)
        self._engine.evict_slots([s])
        self._free.append(s)
        self._ckpts.append(ckpt)
        self.report.preemptions += 1
        faults.note("preempt")

    def _resume_checkpoints(self, now: float) -> None:
        """Re-admit preempted checkpoints (FIFO) into free slots: restore
        the KV/state rows eagerly, re-arm the sampler slot at the exact
        token index already emitted (``set_slot`` + ``advance`` — the
        determinism contract), and restore the current token/position.  No
        prefill launch is issued."""
        while self._ckpts and self._free:
            h = self._ckpts[0]["handle"]
            if (self._kv_budget is not None
                    and self._live_kv + self._kv_need[h.index]
                    > self._kv_budget):
                break
            s = self._free[0]
            try:
                self._engine.restore_slot(s, self._ckpts[0]["state"])
            except faults.PageAllocOOM as err:
                self._degrade_on_oom(err)
                break
            self._pressure = 0
            ckpt = self._ckpts.popleft()
            self._free.popleft()
            self._sampler.set_slot(s, h.sampling)
            self._sampler.advance([s], len(h.tokens))
            self._slot_handle[s] = h
            self._cur[s] = ckpt["cur"]
            self._pos[s] = ckpt["pos"]
            if self._kv_budget is not None:
                self._live_kv += self._kv_need[h.index]
            h.status = "running"
            self.report.resumes += 1
            faults.note("resume")

    def _maybe_preempt(self) -> None:
        """Injected preemption (chaos schedules): every
        ``spec.preempt_every`` decode ticks, preempt the lowest-slot
        running request (continuous only — static waves drain in place).
        Progress is guaranteed: the checkpoint resumes at the next
        admission and the tick clock only advances while decoding, so a
        preempt/resume cycle always decodes between preemptions."""
        if self.serve.scheduler != "continuous":
            return
        fp = faults.current()
        if fp is None or fp.spec.preempt_every <= 0:
            return
        if self._preempt_due_at is None:
            self._preempt_due_at = fp.spec.preempt_every
        if self._ticks < self._preempt_due_at:
            return
        victims = [s for s in range(self._b)
                   if self._slot_handle[s] is not None
                   and not self._slot_handle[s].finished]
        if not victims:
            return
        self._preempt_due_at = self._ticks + fp.spec.preempt_every
        fp.note("injected:preempt")
        self._preempt_slot(min(victims), self._now())

    def _degrade_on_oom(self, err: Exception) -> None:
        """Memory-pressure degradation ladder (counted, escalating with
        consecutive pressure): (1) defer the admission — the handle is
        already requeued at the head; (2) demote live device page frames
        to the host tier; (3) shrink the fused decode-chunk cap so frames
        recycle at finer granularity.  Fails loudly (re-raise) only when
        the request is unservable: no fault plan armed and nothing live
        whose eviction could ever free frames."""
        if faults.current() is None and not self._any_live():
            raise err
        self._pressure += 1
        self.report.degrade_deferrals += 1
        faults.note("recovered:admission-deferral")
        pages = self._engine.pages
        if self._pressure >= 2 and pages is not None:
            moved = pages.demote_device_frames(pages.pages_per_seq)
            self.report.page_demotions += moved
        if self._pressure >= 3:
            cap = int(self.serve.decode_chunk
                      or getattr(self.plan, "decode_chunk", 1) or 1)
            base = self._shrink_cap if self._shrink_cap is not None else cap
            self._shrink_cap = max(1, base // 2)
            self._shrink_ticks = 16
            self.report.chunk_shrinks += 1
            faults.note("recovered:chunk-shrink")

    # -- shared prefill / decode / finish ----------------------------------
    def _prefill_wave(self, handles: List[RequestHandle],
                      slots: List[int]) -> None:
        """One batched prefill of ``handles`` into ``slots``: writes their
        KV/state rows, arms their sampler slots, and emits each request's
        FIRST token (sampled from the prefill logits).

        With the prefix cache on, the wave is partitioned: HITS are
        admitted per handle through ``engine.prefill_prefix_hit`` (the
        stored prefix pages are copied in; only the suffix is computed —
        zero prefill launches for the shared span), MISSES take the
        batched prefill and donate their prefix rows to the store
        afterwards.  Tokens are identical either way (per-slot seeded
        sampling; copied KV equals recomputed KV)."""
        engine, sampler = self._engine, self._sampler
        t0 = self._now()
        hits: List = []
        misses, miss_slots = list(handles), list(slots)
        if self._prefix is not None:
            hits, misses, miss_slots = [], [], []
            for h, s in zip(handles, slots):
                kp = self._prefix.key(h.prompt)
                kvs = None if kp is None else self._prefix.get(kp[0])
                if kvs is not None:
                    hits.append((h, s, kp[1], kvs))
                else:
                    misses.append(h)
                    miss_slots.append(s)
        for h, s in zip(handles, slots):
            sampler.set_slot(s, h.sampling)
        tok0: Dict[int, int] = {}
        if misses:
            self.report.prefill_tokens += sum(len(h.prompt) for h in misses)
            ptoks, lens = pad_requests(misses, self.serve.pad_id)
            lg = engine.prefill_slots(jnp.asarray(ptoks), miss_slots,
                                      lengths=lens)
            for s, tk in zip(miss_slots,
                             np.asarray(sampler.sample(lg, miss_slots))):
                tok0[s] = int(tk)
            if self._prefix is not None:
                for h, s in zip(misses, miss_slots):
                    kp = self._prefix.key(h.prompt)
                    if kp is not None:
                        self._prefix.put(
                            kp[0], engine.read_prefix_rows(s, kp[1])
                        )
        for h, s, pspan, kvs in hits:
            self.report.prefill_tokens += len(h.prompt) - pspan
            lg = engine.prefill_prefix_hit(s, h.prompt, kvs, pspan)
            tok0[s] = int(np.asarray(sampler.sample(lg, [s]))[0])
        now = self._now()
        self.report.prefill_s += now - t0
        if self._wave is not None:
            self._wave["prefill_s"] += now - t0
        eos = self.serve.eos_id
        for h, s in zip(handles, slots):
            tk = tok0[s]
            self._slot_handle[s] = h
            self._pos[s] = len(h.prompt)
            self._cur[s] = tk
            h.status = "running"
            h.admit_s = t0
            h.first_token_s = now
            h._emit(tk)
            if self._wave is not None:
                self._wave["rows"][s] = [tk]
            if h.decode_len <= 1 or (eos is not None and tk == eos):
                self._finish_slot(s, now)

    def _chunk_T(self) -> int:
        """Decode ticks to run this step as ONE fused multi-token chunk.

        Chunking is the module-batching thesis applied to the scheduler:
        when no admission or eviction can fall due mid-chunk, ``T`` decode
        ticks cost one device dispatch (``engine.decode_chunk``) instead of
        ``T``.  ``T`` is capped by the plan's ``decode_chunk`` (or the
        ``ServeConfig`` override) and clamped to the SHORTEST remaining
        decode among unfinished slots, so every finish event still lands
        exactly at a chunk boundary (timestamps, eviction and §5.1 waste
        accounting are tick-identical to per-tick stepping).  Falls back to
        1 when: an ``eos_id`` is set (finishes are unpredictable), the
        engine is not fused-eligible (streamed weights keep the per-layer
        loop), or — continuous mode — a queued arrival could be admitted
        into a free slot mid-chunk.
        """
        cap = self.serve.decode_chunk or getattr(self.plan, "decode_chunk", 1)
        if self._shrink_ticks > 0:
            # memory-pressure degradation stage 3: finer chunks recycle
            # page frames at finer granularity (decays back to the
            # configured cap after _shrink_ticks steps)
            cap = min(int(cap), self._shrink_cap)
            self._shrink_ticks -= 1
            if self._shrink_ticks == 0:
                self._shrink_cap = None
        fp = faults.current()
        if (fp is not None and fp.spec.preempt_every > 0
                and self.serve.scheduler == "continuous"):
            # an injected preemption can only land at a chunk boundary —
            # clamp T so the tick clock stops exactly at the next scheduled
            # preempt (chunking-only: the decoded tokens are unchanged)
            due = (self._preempt_due_at if self._preempt_due_at is not None
                   else fp.spec.preempt_every)
            if due > self._ticks:
                cap = min(int(cap), due - self._ticks)
        if cap <= 1 or self.serve.eos_id is not None:
            return 1
        if not self._engine.fused_eligible():
            return 1
        if self._wave is not None:
            rem = [h.decode_len - len(h.tokens)
                   for h, d in zip(self._wave["handles"], self._wave["done"])
                   if not d]
        else:
            if (self._pending or self._ckpts) and self._free:
                return 1               # a due arrival/resume could admit
            rem = [h.decode_len - len(h.tokens)
                   for h in self._slot_handle
                   if h is not None and not h.finished]
        if not rem:
            return 1
        return max(1, min(int(cap), min(rem)))

    @hot_path
    def _decode_tick(self, T: int = 1) -> None:
        """``T`` module-batched decode ticks over the full engine batch —
        ONE fused device dispatch when the engine's fused path is eligible;
        live slots emit their sampled tokens tick by tick, finishers are
        handed to the policy's finish path."""
        engine, sampler = self._engine, self._sampler
        wave = self._wave
        # rows the scheduler advances each tick: wave slots (finished
        # members keep stepping until the drain) or handle-owning slots.
        # Dead rows hold their stale token/position inside the chunk,
        # exactly like per-tick stepping never updates a free slot.
        live = np.zeros(self._b, bool)
        if wave is not None:
            live[wave["slots"]] = True
        else:
            live[[s for s in range(self._b)
                  if self._slot_handle[s] is not None]] = True
        t0 = self._now()
        toks = engine.decode_chunk(
            jnp.asarray(self._cur), jnp.asarray(self._pos), sampler, T,
            live=live,
        )
        with sanitizer.allowed("token-readback"):
            mat = np.asarray(toks)  # lint: allow[MG101] the per-chunk token readback — the ONE planned d2h sync per scheduler tick
        now = self._now()
        self._ticks += T
        self.report.decode_s += now - t0
        if wave is not None:
            wave["decode_s"] += now - t0
        counted = len(wave["slots"]) if wave is not None else self._b
        eos = self.serve.eos_id
        for t in range(T):
            nxt = mat[:, t]
            live = [s for s in range(self._b)
                    if self._slot_handle[s] is not None
                    and not self._slot_handle[s].finished]
            self.report.decode_slot_steps += counted
            self.report.wasted_slot_steps += counted - len(live)
            for s in live:
                h = self._slot_handle[s]
                tk = int(nxt[s])
                h._emit(tk)
                if len(h.tokens) >= h.decode_len or (
                        eos is not None and tk == eos):
                    self._finish_slot(s, now)
            if wave is not None:
                # the wave keeps stepping finished slots until its slowest
                # member drains — record their raw chain (paper §5.1 static
                # batches; the waste is the mode's defining metric)
                wave["ticks"] += 1
                for s in wave["slots"]:
                    wave["rows"][s].append(int(nxt[s]))
                    self._cur[s] = nxt[s]
                    self._pos[s] += 1
                if all(wave["done"]):
                    self._close_wave()
                    break              # _chunk_T ends chunks at the drain
            else:
                for s in range(self._b):
                    if self._slot_handle[s] is not None:
                        self._cur[s] = nxt[s]
                        self._pos[s] += 1

    def _finish_slot(self, s: int, now: float) -> None:
        h = self._slot_handle[s]
        h.status = "finished"
        h.finish_s = now
        if self._wave is not None:                      # static: keep the
            self._wave["done"][self._wave["slots"].index(s)] = True
            return                                      # slot until drain
        h.decode_steps = len(h.tokens) - 1
        self.report.request_results.append(h.result())
        if self._kv_budget is not None:
            self._live_kv -= self._kv_need[h.index]
        self._slot_handle[s] = None
        self._sampler.clear_slot(s)
        self._engine.evict_slots([s])
        self._free.append(s)

    def _close_wave(self) -> None:
        """Static wave drained: record its BatchResult (raw token matrix,
        old-protocol shape) and per-request results, then free the slots."""
        wave, self._wave = self._wave, None
        ticks = wave["ticks"]
        for h, s in zip(wave["handles"], wave["slots"]):
            h.decode_steps = ticks
            self.report.request_results.append(h.result())
            self._slot_handle[s] = None
            self._sampler.clear_slot(s)
        self._engine.evict_slots(wave["slots"])
        self._free = deque(range(self._b))
        mat = np.asarray([wave["rows"][s] for s in wave["slots"]], np.int64)
        self.report.results.append(BatchResult(
            mat, wave["prefill_s"], wave["decode_s"],
            self._drain_engine_stats(),
        ))


def _host_kv_budget(cfg: ModelConfig, hw: HardwareProfile) -> float:
    from repro.core.planner import host_kv_budget

    return host_kv_budget(cfg, hw)
