"""Back-compat scheduling surface over ``serving.server.Server``.

The scheduler core lives in ``repro.serving.server``: one step-driven loop
(``Server.step``) under two admission policies — ``static`` accumulated
waves (paper §5.1) and ``continuous`` in-flight batching — with
per-request ``SamplingParams``, open-loop arrivals, and request-lifecycle
metrics (TTFT / TPOT / queue wait).  This module re-exports the request
and report types from there and keeps ``serve_dataset`` as a thin
offline-protocol wrapper so existing callers and tests are untouched.
"""
from __future__ import annotations

from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core.dag_builder import Plan
from repro.core.hardware import HardwareProfile
from repro.serving.sampling import SamplingParams  # noqa: F401  (re-export)
from repro.serving.server import (  # noqa: F401  (re-exports)
    BatchResult,
    Request,
    RequestHandle,
    RequestResult,
    ServeConfig,
    Server,
    ServeReport,
    StreamConfig,
    pad_requests,
)
from repro.serving.weights import ParamStore

__all__ = [
    "BatchResult", "Request", "RequestHandle", "RequestResult",
    "SamplingParams", "ServeConfig", "Server", "ServeReport", "StreamConfig",
    "pad_requests", "serve_dataset",
]


def serve_dataset(
    cfg: ModelConfig,
    params,
    requests: List[Request],
    plan: Plan,
    decode_len: int,
    max_seq: Optional[int] = None,
    expert_path: str = "grouped",
    scheduler: str = "static",
    pad_id: int = 0,
    eos_id: Optional[int] = None,
    max_prompt_len: Optional[int] = None,
    grouped_prefill: bool = True,
    stream_weights: bool = False,
    resident_bytes: Optional[float] = None,
    prefetch: bool = True,
    hw: Optional[HardwareProfile] = None,
    store: Optional[ParamStore] = None,
    kv_page_tokens: int = 0,
    device_kv_gb: Optional[float] = None,
    prefix_cache: bool = False,
    sctx=None,
    ep_chunks: int = 1,
    faults=None,
) -> ServeReport:
    """Serve a fixed request list to completion (the offline protocol).

    .. deprecated::
        ``serve_dataset`` is a back-compat wrapper over
        ``repro.serving.server.Server`` — new code should build a
        ``Server`` with ``ServeConfig`` / ``StreamConfig`` and use
        ``submit`` / ``step`` / ``run`` directly, which also opens online
        arrivals (``Request.arrival_s``), per-request sampling
        (``Request.sampling``), and streaming token callbacks.

    ``scheduler`` selects static accumulated waves vs continuous in-flight
    batching.  Per-request ``decode_len`` is honored (``decode_len`` is the
    fallback for requests with a zero/None field); ``eos_id`` finishes a
    sequence early.  ``expert_path`` selects the engine's MoE stage
    ('grouped' = one on-device dispatch per MoE layer, 'loop' = the
    sequential per-expert oracle).

    ``stream_weights=True`` executes through the streamed parameter store:
    only the greedy ``resident_bytes`` set (default ``plan.s_params``) is
    pinned on device, the rest is served through the engine's
    double-buffered async prefetch (``prefetch=False`` degrades to
    serialized fetches); transfer accounting lands in
    ``ServeReport.htod_gb`` / ``prefetch_wait_s``.  A pre-built ``store``
    overrides the residency arguments (one store is always shared by every
    engine the scheduler creates).

    ``sctx`` (a mesh ``ShardCtx`` with ``moe_dispatch`` 'a2a'/'psum') runs
    the engine expert-parallel across the mesh's model axis; ``ep_chunks``
    picks the pipelined all-to-all chunk count (``repro.distributed``).

    ``hw`` enables memory-aware admission in the continuous scheduler:
    a queued request is admitted only while every in-flight sequence's
    offloaded KV/state (at its full prompt+decode extent) fits the Eq. 2
    host budget (``m_c - S_Model``) — over-long prompts wait instead of
    overflowing host memory (``ServeReport.admission_deferrals`` counts the
    waits).  A request that could never fit raises ``ValueError``.

    ``faults`` arms a deterministic fault-injection plan for the run (a
    ``repro.faults.FaultPlan`` / ``FaultSpec`` / spec string — see
    ``ServeConfig.faults``); ``None`` leaves serving byte-identical to an
    unarmed build.
    """
    assert scheduler in ("static", "continuous"), scheduler
    if not requests:
        return ServeReport(scheduler=scheduler)
    server = Server(
        cfg, params, plan,
        serve=ServeConfig(
            scheduler=scheduler, decode_len=decode_len, max_seq=max_seq,
            max_prompt_len=max_prompt_len, pad_id=pad_id, eos_id=eos_id,
            expert_path=expert_path, grouped_prefill=grouped_prefill, hw=hw,
            kv_page_tokens=kv_page_tokens, device_kv_gb=device_kv_gb,
            prefix_cache=prefix_cache, sctx=sctx, ep_chunks=ep_chunks,
            faults=faults,
        ),
        stream=StreamConfig(
            stream_weights=stream_weights, resident_bytes=resident_bytes,
            prefetch=prefetch,
        ),
        store=store,
    )
    for r in requests:
        server.submit(r)
    return server.run()
