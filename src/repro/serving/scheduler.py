"""Offline inference scheduler: dataset -> accumulated batches -> engine.

The paper's workload: complete an entire dataset (Table 4) with prompts
padded/truncated to a uniform length.  The scheduler slices the request set
into accumulated batches of ``B`` sequences (from the planner), runs each
through the module-batching engine, and reports aggregate timing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    decode_len: int


@dataclass
class BatchResult:
    tokens: np.ndarray            # (B, decode_len)
    prefill_s: float
    decode_s: float
    expert_tokens_dropped: int = 0   # routed copies over the b_e capacity


@dataclass
class ServeReport:
    results: List[BatchResult] = field(default_factory=list)

    @property
    def total_s(self) -> float:
        return sum(r.prefill_s + r.decode_s for r in self.results)

    @property
    def decode_tokens(self) -> int:
        return sum(r.tokens.size for r in self.results)

    @property
    def expert_tokens_dropped(self) -> int:
        return sum(r.expert_tokens_dropped for r in self.results)

    @property
    def decode_throughput(self) -> float:
        d = sum(r.decode_s for r in self.results)
        return self.decode_tokens / d if d > 0 else 0.0


def pad_requests(requests: List[Request], pad_id: int = 0) -> np.ndarray:
    """Pad/truncate to uniform length (paper §5.1 evaluation protocol)."""
    S = max(len(r.prompt) for r in requests)
    out = np.full((len(requests), S), pad_id, np.int32)
    for i, r in enumerate(requests):
        p = r.prompt[:S]
        out[i, : len(p)] = p
    return out


def serve_dataset(
    cfg: ModelConfig,
    params,
    requests: List[Request],
    plan: Plan,
    decode_len: int,
    max_seq: Optional[int] = None,
    expert_path: str = "grouped",
) -> ServeReport:
    """Serve ``requests`` in accumulated batches of ``plan.B``.

    ``expert_path`` selects the engine's MoE stage ('grouped' = one
    on-device dispatch per MoE layer, 'loop' = the sequential per-expert
    oracle) so the loop-vs-grouped speedup is directly measurable from the
    report's timings.
    """
    report = ServeReport()
    B = max(1, plan.B)
    for lo in range(0, len(requests), B):
        chunk = requests[lo : lo + B]
        prompts = pad_requests(chunk)
        engine = ModuleBatchingEngine(
            cfg, params, plan,
            max_seq=max_seq or prompts.shape[1] + decode_len,
            expert_path=expert_path,
        )
        t0 = time.perf_counter()
        logits = engine.prefill(jnp.asarray(prompts))
        logits.block_until_ready()
        t1 = time.perf_counter()
        toks = [np.asarray(jnp.argmax(logits, axis=-1))]
        for t in range(decode_len - 1):
            lg = engine.decode_step(jnp.asarray(toks[-1]), prompts.shape[1] + t)
            toks.append(np.asarray(jnp.argmax(lg, axis=-1)))
        t2 = time.perf_counter()
        stats = engine.sync_stats()      # fold device-side drop counters in
        report.results.append(
            BatchResult(np.stack(toks, 1), t1 - t0, t2 - t1,
                        stats.expert_tokens_dropped)
        )
    return report
