"""Inference scheduler: dataset -> module-batched engine, static or continuous.

Two scheduling modes over the same module-batching engine:

* ``static`` — the paper's offline protocol (§5.1): slice the request set
  into accumulated batches of ``B`` sequences, run each batch prefill +
  decode to the batch's longest ``decode_len``.  Ragged prompts are
  right-padded and masked (exact, see ``model.forward``); sequences that
  finish early still occupy their slot until the batch drains (counted in
  ``wasted_slot_steps``).

* ``continuous`` — in-flight batching on top of module-based batching
  (ROADMAP item; vLLM-style): when a sequence finishes (its ``decode_len``
  reached, or EOS), its batch slot, KV-cache rows and SSM state are evicted
  and immediately recycled — the next queued request is prefilled into the
  freed slot (``engine.prefill_slots``) and rejoins the shared decode loop.
  The accumulated batch stays *full*, not just large, which is what closes
  the gap to the hardware limit on mixed-length workloads (MoE-Lens /
  MoE-Lightning).

Both modes honor per-request ``decode_len`` and — when the plan's expert
capacity ``b_e`` admits every routed token (capacity drops depend on batch
composition, which the two modes schedule differently) — produce identical
tokens per request.  On mixed-length workloads with more requests than
batch slots, the continuous mode executes strictly fewer decode-step·slot
units (asserted in tests/test_serving.py); with the queue exhausted it
degrades to static-like draining of the in-flight batch.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.core.hardware import HardwareProfile
from repro.serving.kvcache import evict_rows
from repro.serving.weights import ParamStore


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    decode_len: int


@dataclass
class BatchResult:
    tokens: np.ndarray            # (B, decode_len) raw batch tokens (static)
    prefill_s: float
    decode_s: float
    expert_tokens_dropped: int = 0   # routed copies over the b_e capacity


@dataclass
class RequestResult:
    index: int                    # position in the input request list
    tokens: np.ndarray            # (n,) generated tokens (<= decode_len; EOS cut)
    latency_s: float              # admission -> last token (incl. its prefill)
    decode_steps: int             # decode steps while this request was live


@dataclass
class ServeReport:
    results: List[BatchResult] = field(default_factory=list)
    request_results: List[RequestResult] = field(default_factory=list)
    scheduler: str = "static"
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_slot_steps: int = 0    # decode steps x batch slots executed
    wasted_slot_steps: int = 0    # slot-steps spent on finished/empty slots
    weight_htod_bytes: int = 0    # streamed weight bytes copied host->device
    prefetch_wait_s: float = 0.0  # stall waiting on weight transfers
    admission_deferrals: int = 0  # admissions blocked by the Eq. 2 KV budget
    _expert_dropped: int = 0      # drops counted outside BatchResults

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    @property
    def htod_gb(self) -> float:
        """Streamed weight traffic in GB (0 when everything is resident)."""
        return self.weight_htod_bytes / 1e9

    @property
    def decode_tokens(self) -> int:
        """Valid generated tokens (per-request decode_len / EOS honored)."""
        return sum(r.tokens.size for r in self.request_results)

    @property
    def expert_tokens_dropped(self) -> int:
        return self._expert_dropped + sum(
            r.expert_tokens_dropped for r in self.results
        )

    @property
    def decode_throughput(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Fraction of executed decode slot-steps that produced live tokens."""
        if self.decode_slot_steps == 0:
            return 1.0
        return 1.0 - self.wasted_slot_steps / self.decode_slot_steps

    @property
    def mean_latency_s(self) -> float:
        rr = self.request_results
        return sum(r.latency_s for r in rr) / len(rr) if rr else 0.0


def pad_requests(
    requests: List[Request],
    pad_id: int = 0,
    max_prompt_len: Optional[int] = None,
):
    """Right-pad a request chunk to its longest prompt.

    Prompts longer than ``max_prompt_len`` (when given) are truncated to it
    first.  Returns ``(tokens (B, S), lengths (B,))`` — the lengths are what
    make the padding exact downstream (prefill masks pads and gathers each
    sequence's logits at its true last token).
    """
    prompts = []
    for r in requests:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        if max_prompt_len is not None:
            p = p[:max_prompt_len]
        prompts.append(p)
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    S = max(1, int(lengths.max())) if prompts else 1
    out = np.full((len(requests), S), pad_id, np.int32)
    for i, p in enumerate(prompts):
        out[i, : len(p)] = p
    return out, lengths


def _trim_eos(tokens: np.ndarray, eos_id: Optional[int]) -> np.ndarray:
    """Cut a token stream after (and including) the first EOS."""
    if eos_id is None:
        return tokens
    hits = np.nonzero(tokens == eos_id)[0]
    return tokens[: int(hits[0]) + 1] if hits.size else tokens


def serve_dataset(
    cfg: ModelConfig,
    params,
    requests: List[Request],
    plan: Plan,
    decode_len: int,
    max_seq: Optional[int] = None,
    expert_path: str = "grouped",
    scheduler: str = "static",
    pad_id: int = 0,
    eos_id: Optional[int] = None,
    max_prompt_len: Optional[int] = None,
    grouped_prefill: bool = True,
    stream_weights: bool = False,
    resident_bytes: Optional[float] = None,
    prefetch: bool = True,
    hw: Optional[HardwareProfile] = None,
    store: Optional[ParamStore] = None,
) -> ServeReport:
    """Serve ``requests`` with ``plan.B`` batch slots.

    ``scheduler`` selects static accumulated batches vs continuous in-flight
    batching (see module docstring).  Per-request ``decode_len`` is honored
    (``decode_len`` is the fallback for requests with a zero/None field);
    ``eos_id`` finishes a sequence early.  ``expert_path`` selects the
    engine's MoE stage ('grouped' = one on-device dispatch per MoE layer,
    'loop' = the sequential per-expert oracle).

    ``stream_weights=True`` executes through the streamed parameter store:
    only the greedy ``resident_bytes`` set (default ``plan.s_params``) is
    pinned on device, the rest is served through the engine's
    double-buffered async prefetch (``prefetch=False`` degrades to
    serialized fetches); transfer accounting lands in
    ``ServeReport.htod_gb`` / ``prefetch_wait_s``.  A pre-built ``store``
    overrides the residency arguments (one store is always shared by every
    engine the scheduler creates).

    ``hw`` enables memory-aware admission in the continuous scheduler:
    a queued request is admitted only while every in-flight sequence's
    offloaded KV/state (at its full prompt+decode extent) fits the Eq. 2
    host budget (``m_c - S_Model``) — over-long prompts wait instead of
    overflowing host memory (``ServeReport.admission_deferrals`` counts the
    waits).  A request that could never fit raises ``ValueError``.
    """
    assert scheduler in ("static", "continuous"), scheduler
    report = ServeReport(scheduler=scheduler)
    if not requests:
        return report
    if store is None:
        # ONE store serves every engine (the static scheduler builds one
        # engine per request chunk): the host copy of the streamed set and
        # the residency split are made once, not per chunk
        store = ParamStore.build(cfg, params, plan,
                                 stream_weights=stream_weights,
                                 resident_bytes=resident_bytes,
                                 prefetch=prefetch)
    engine_kw = dict(
        expert_path=expert_path, grouped_prefill=grouped_prefill, store=store,
    )
    dec = [max(1, int(r.decode_len or decode_len)) for r in requests]
    plens = [
        min(len(r.prompt), max_prompt_len) if max_prompt_len is not None
        else len(r.prompt)
        for r in requests
    ]
    if max_seq is not None:
        for i, (pl, d) in enumerate(zip(plens, dec)):
            if pl + d > max_seq:
                raise ValueError(
                    f"request {i}: prompt length {pl} + decode_len {d} "
                    f"exceeds the engine's max_seq={max_seq}; pass "
                    f"max_prompt_len to truncate long prompts"
                )
    if scheduler == "static":
        _serve_static(cfg, params, requests, dec, plan, report, max_seq,
                      engine_kw, pad_id, eos_id, max_prompt_len)
    else:
        _serve_continuous(cfg, params, requests, dec, plan, report, max_seq,
                          engine_kw, pad_id, eos_id, max_prompt_len, hw)
    return report


# ---------------------------------------------------------------------------
# Static accumulated batches (paper §5.1)
# ---------------------------------------------------------------------------
def _serve_static(cfg, params, requests, dec, plan, report, max_seq,
                  engine_kw, pad_id, eos_id, max_prompt_len) -> None:
    B = max(1, plan.B)
    for lo in range(0, len(requests), B):
        chunk = requests[lo : lo + B]
        cdec = dec[lo : lo + B]
        prompts, lengths = pad_requests(chunk, pad_id, max_prompt_len)
        b, S = prompts.shape
        steps = max(cdec)
        engine = ModuleBatchingEngine(
            cfg, params, plan,
            max_seq=max_seq or S + steps,
            **engine_kw,
        )
        t0 = time.perf_counter()
        logits = engine.prefill(jnp.asarray(prompts), lengths=lengths)
        logits.block_until_ready()
        t1 = time.perf_counter()
        toks = [np.asarray(jnp.argmax(logits, axis=-1))]
        tick = [t1]                        # wall time after each token column
        pos = jnp.asarray(lengths)
        for t in range(steps - 1):
            lg = engine.decode_step(jnp.asarray(toks[-1]), pos + t)
            toks.append(np.asarray(jnp.argmax(lg, axis=-1)))
            tick.append(time.perf_counter())
        t2 = tick[-1]
        stats = engine.sync_stats()      # fold device-side counters in
        report.weight_htod_bytes += stats.weight_htod_bytes
        report.prefetch_wait_s += stats.prefetch_wait_s
        mat = np.stack(toks, 1)                             # (b, steps)
        for i in range(b):
            out = _trim_eos(mat[i, : cdec[i]], eos_id)
            report.request_results.append(RequestResult(
                index=lo + i,
                tokens=out,
                latency_s=tick[out.size - 1] - t0,
                decode_steps=steps - 1,
            ))
        useful = sum(r.tokens.size - 1 for r in report.request_results[-b:])
        report.decode_slot_steps += b * (steps - 1)
        report.wasted_slot_steps += b * (steps - 1) - useful
        report.prefill_s += t1 - t0
        report.decode_s += t2 - t1
        report.results.append(
            BatchResult(mat, t1 - t0, t2 - t1, stats.expert_tokens_dropped)
        )


# ---------------------------------------------------------------------------
# Continuous in-flight batching (admission + eviction)
# ---------------------------------------------------------------------------
def _serve_continuous(cfg, params, requests, dec, plan, report, max_seq,
                      engine_kw, pad_id, eos_id, max_prompt_len, hw) -> None:
    # never allocate more slots than there are requests: every decode step
    # runs the full engine batch, so surplus slots would be pure waste
    B = max(1, min(plan.B, len(requests)))
    prompts = []
    for r in requests:
        p = np.asarray(r.prompt, np.int32).reshape(-1)
        prompts.append(p[:max_prompt_len] if max_prompt_len is not None else p)
    M = max_seq or max(len(p) + d for p, d in zip(prompts, dec))
    engine = ModuleBatchingEngine(cfg, params, plan, max_seq=M, **engine_kw)
    engine.init_cache(B)

    queue = deque(range(len(requests)))
    slot_req = np.full(B, -1)             # request index per slot (-1 = free)
    pos = np.zeros(B, np.int64)           # next decode position per slot
    cur = np.zeros(B, np.int32)           # last emitted token per slot
    gen: List[List[int]] = [[] for _ in range(B)]
    admit_t = np.zeros(B)
    free = list(range(B))

    # Eq. 2 admission budget: every in-flight sequence's offloaded KV/state
    # at its FULL prompt+decode extent must fit m_c - S_Model (admitting on
    # the worst case means a sequence can never outgrow the host mid-decode)
    from repro.core.planner import host_kv_budget

    kv_budget = None if hw is None else host_kv_budget(cfg, hw)
    kv_need = [
        W.kv_bytes_per_seq(cfg, len(p) + d) for p, d in zip(prompts, dec)
    ]
    if kv_budget is not None:
        # fail BEFORE any work: a request whose KV can never fit would
        # otherwise drain the queue for minutes and then raise mid-serve
        for i, need in enumerate(kv_need):
            if need > kv_budget:
                raise ValueError(
                    f"request {i}: KV/state bytes {need:.3e} can never fit "
                    f"the Eq. 2 host budget {kv_budget:.3e} (host_mem - "
                    f"model); truncate with max_prompt_len or shrink "
                    f"decode_len"
                )
    live_kv = 0.0

    def finish(slot: int, now: float) -> None:
        nonlocal live_kv
        report.request_results.append(RequestResult(
            index=int(slot_req[slot]),
            tokens=np.asarray(gen[slot], np.int32),
            latency_s=now - admit_t[slot],
            decode_steps=len(gen[slot]) - 1,
        ))
        if kv_budget is not None:
            live_kv -= kv_need[int(slot_req[slot])]
        slot_req[slot] = -1
        gen[slot] = []
        engine.cache = evict_rows(engine.cache, [slot])
        free.append(slot)

    def admit() -> None:
        """Prefill queued requests into freed slots (one batched prefill per
        admission wave; insta-finishers — decode_len 1 / EOS on the first
        token — free their slot again, so loop until stable).  With an
        Eq. 2 budget, the queue head WAITS while its KV bytes don't fit
        next to the in-flight sequences' (FIFO — later smaller requests are
        not reordered past it)."""
        nonlocal live_kv
        while free and queue:
            slots, idxs = [], []
            while free and queue:
                i = queue[0]
                if kv_budget is not None and live_kv + kv_need[i] > kv_budget:
                    break              # head waits for an eviction
                queue.popleft()
                slots.append(free.pop(0))
                idxs.append(i)
                live_kv += kv_need[i]
            if not idxs:
                break                  # nothing admissible this attempt
            batch = [Request(prompts[i], dec[i]) for i in idxs]
            ptoks, lens = pad_requests(batch, pad_id)
            t0 = time.perf_counter()
            lg = engine.prefill_slots(jnp.asarray(ptoks), slots, lengths=lens)
            tok0 = np.asarray(jnp.argmax(lg, axis=-1))
            now = time.perf_counter()
            report.prefill_s += now - t0
            for s, i, tk, ln in zip(slots, idxs, tok0, lens):
                slot_req[s] = i
                pos[s] = int(ln)
                cur[s] = tk
                gen[s] = [int(tk)]
                admit_t[s] = t0
                if dec[i] <= 1 or (eos_id is not None and tk == eos_id):
                    finish(s, now)
        # counted ONCE per admission attempt: the head is leaving this
        # attempt memory-blocked despite a free slot
        if (kv_budget is not None and queue and free
                and live_kv + kv_need[queue[0]] > kv_budget):
            report.admission_deferrals += 1

    admit()
    while (slot_req >= 0).any():
        active = slot_req >= 0
        t0 = time.perf_counter()
        lg = engine.decode_step(
            jnp.asarray(cur), jnp.asarray(np.minimum(pos, M - 1))
        )
        nxt = np.asarray(jnp.argmax(lg, axis=-1))
        now = time.perf_counter()
        report.decode_s += now - t0
        report.decode_slot_steps += B
        report.wasted_slot_steps += int(B - active.sum())
        for s in np.nonzero(active)[0]:
            gen[s].append(int(nxt[s]))
            cur[s] = nxt[s]
            pos[s] += 1
            i = slot_req[s]
            if len(gen[s]) >= dec[i] or (eos_id is not None and nxt[s] == eos_id):
                finish(int(s), now)
        admit()

    stats = engine.sync_stats()
    report._expert_dropped += stats.expert_tokens_dropped
    report.weight_htod_bytes += stats.weight_htod_bytes
    report.prefetch_wait_s += stats.prefetch_wait_s
    report.request_results.sort(key=lambda r: r.index)
