"""Serving surface: the request-lifecycle server, offline wrapper, sampling,
arrival processes, the paged tiered KV cache and the streamed parameter
store."""
from repro.serving import arrivals
from repro.serving.cache import CacheConfig, KVPageTable, PrefixStore
from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill
from repro.serving.sampling import BatchSampler, SamplingParams
from repro.serving.scheduler import serve_dataset
from repro.serving.server import (
    BatchResult,
    Request,
    RequestHandle,
    RequestResult,
    ServeConfig,
    Server,
    ServeReport,
    StreamConfig,
    pad_requests,
)
from repro.serving.weights import ParamStore

__all__ = [
    "arrivals",
    "BatchResult",
    "BatchSampler",
    "cache_from_prefill",
    "CacheConfig",
    "greedy_generate",
    "KVPageTable",
    "pad_requests",
    "ParamStore",
    "PrefixStore",
    "Request",
    "RequestHandle",
    "RequestResult",
    "SamplingParams",
    "serve_dataset",
    "ServeConfig",
    "Server",
    "ServeReport",
    "StreamConfig",
]
