from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill

__all__ = ["greedy_generate", "cache_from_prefill"]
