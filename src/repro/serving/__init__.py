from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill
from repro.serving.weights import ParamStore

__all__ = ["greedy_generate", "cache_from_prefill", "ParamStore"]
