"""KV-cache conversion and (host-)offloaded cache management.

``model.prefill`` returns raw per-layer K/V stacked over layer groups;
decode expects pre-allocated (possibly ring-buffer) caches.  This module
converts between the two, handling sliding-window ring alignment (absolute
position p lives in slot ``p % window``), and provides the slot-wise
insert/evict primitives the continuous scheduler uses to recycle batch
slots mid-flight (a finished sequence's KV rows and SSM state are
overwritten by the next admitted request).

These free functions are the CONTIGUOUS-buffer primitives of the cache
API; the paged tier (``serving.cache.KVPageTable``) builds on them —
``aligned_kv`` produces the span-aligned rows its page splitter consumes,
and ``insert_prefill_rows``/``evict_rows`` remain the Mode A (fully
device-resident) fast path the engine routes through.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import TraceKeySet, register_jit
from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def aligned_kv(
    cfg: ModelConfig, k: jax.Array, v: jax.Array, span: int
) -> Tuple[jax.Array, jax.Array]:
    """Raw prefill K/V ``(..., S, K, hd)`` -> decode-ready ``(..., span, ...)``.

    Pads/truncates to ``span`` slots; with a sliding window longer prompts
    are ring-aligned (absolute position p -> slot ``p % span``).
    """
    *lead, S, K, hd = k.shape
    kf = k.reshape((-1, S, K, hd))
    vf = v.reshape((-1, S, K, hd))
    n = min(S, span)
    nk = jnp.zeros((kf.shape[0], span, K, hd), k.dtype)
    nv = jnp.zeros_like(nk)
    if cfg.sliding_window and S > span:
        pos = jnp.arange(S - n, S)
        slots = pos % span
        nk = nk.at[:, slots].set(kf[:, -n:])
        nv = nv.at[:, slots].set(vf[:, -n:])
    else:
        nk = nk.at[:, :n].set(kf[:, -n:])
        nv = nv.at[:, :n].set(vf[:, -n:])
    shape = tuple(lead) + (span, K, hd)
    return nk.reshape(shape), nv.reshape(shape)


def cache_from_prefill(
    cfg: ModelConfig, caches: List, seq_len: int, max_seq: int
) -> List:
    """Convert prefill caches into decode-ready buffers of span ``max_seq``."""
    pattern = model_mod.layer_pattern(cfg)
    out = []
    for j, (kind, _) in enumerate(pattern):
        slot = caches[j]
        if kind != "attn":
            out.append(slot)                       # SSM state passes through
            continue
        span = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        nk, nv = aligned_kv(cfg, slot["k"], slot["v"], span)
        out.append({"k": nk, "v": nv})
    return out


def insert_prefill_rows(
    cfg: ModelConfig, kind: str, layer_cache: dict, entry: dict,
    rows: Sequence[int],
) -> dict:
    """Insert ONE layer's raw prefill cache ``entry`` into batch rows
    ``rows`` of a decode buffer (ring-aligning KV to the buffer span).

    The slot-insertion invariant lives here and only here: each newcomer's
    FULL row is overwritten — KV beyond its prompt is zeroed, so no state
    of an evicted sequence survives slot recycling.  Shared by the engine's
    layer-major prefill and ``scatter_prefill_rows``.
    """
    rows = jnp.asarray(rows)
    if kind == "attn":
        span = layer_cache["k"].shape[1]
        nk, nv = aligned_kv(cfg, entry["k"], entry["v"], span)
        layer_cache["k"] = layer_cache["k"].at[rows].set(nk)
        layer_cache["v"] = layer_cache["v"].at[rows].set(nv)
    else:
        for key in ("h", "conv"):
            layer_cache[key] = layer_cache[key].at[rows].set(entry[key])
    return layer_cache


def scatter_prefill_rows(
    cfg: ModelConfig, cache: List, caches: List, rows: Sequence[int]
) -> List:
    """Insert newcomers' prefill caches into engine decode buffers at ``rows``.

    ``cache`` is the engine's per-layer (flattened over groups) buffer list;
    ``caches`` the raw ``model.prefill`` output for the newcomer micro-batch
    (stacked over groups).  See ``insert_prefill_rows`` for the invariant.
    """
    pattern = model_mod.layer_pattern(cfg)
    n_pat = len(pattern)
    G = len(cache) // n_pat
    for g in range(G):
        for j, (kind, _) in enumerate(pattern):
            li = g * n_pat + j
            slot = jax.tree.map(lambda a: a[g], caches[j])
            cache[li] = insert_prefill_rows(cfg, kind, cache[li], slot, rows)
    return cache


@register_jit("kvcache.evict", donated=("cache",))
@functools.partial(jax.jit, donate_argnames=("cache",))
def _evict_module(cache, rows):
    return jax.tree.map(
        lambda a: a.at[rows].set(jnp.zeros((), a.dtype)), cache
    )


# distinct padded eviction widths seen: each width is ONE cached trace of
# _evict_module (per cache pytree structure).  Backed by the analysis
# registry's named TraceKeySet — ``evict_retraces()`` is now a thin shim
# over it, and the sanitizer report picks the count up by name.
_EVICT_WIDTHS = TraceKeySet("kvcache.evict_rows")


def evict_retraces() -> int:
    """Number of distinct padded ``rows`` widths ``evict_rows`` has jitted
    with since import (eviction-set sizes 1..8 all share width 8)."""
    return _EVICT_WIDTHS.count


def _pad_evict_rows(rows: Sequence[int]) -> np.ndarray:
    """Pad an eviction set to a fixed trace width (multiple of 8, min 8)
    by repeating the first row as a sentinel: ``rows`` is a traced shape
    in ``_evict_module``, so un-padded calls retrace per distinct set
    size.  Duplicate indices are harmless — zeroing a row twice is
    idempotent."""
    rows = np.asarray(rows, np.int32).reshape(-1)
    width = max(8, int(-(-rows.size // 8) * 8))
    padded = np.full(width, rows[0], np.int32)
    padded[: rows.size] = rows
    _EVICT_WIDTHS.add(width)
    return padded


def evict_rows(cache: List, rows: Sequence[int]) -> List:
    """Zero batch rows across every layer buffer (slot recycling).

    Not required for correctness — decode masks by per-sequence position
    and insertion overwrites whole rows — but keeps freed slots inert
    between eviction and the next admission.  (In the paged Mode B the
    attention entries are empty dicts and the page table recycles frames
    instead — ``ModuleBatchingEngine.evict_slots`` routes both.)

    One jitted launch with the cache pytree DONATED: the rows are zeroed in
    place instead of functionally copying every (B, S, ...) buffer per
    eviction.  The caller's cache reference is consumed — assign the return
    value back (the engine owns the cache between ticks; see the ROADMAP
    donation contract).  The row set is padded to a fixed width so slot
    recycling stays one cached launch across eviction-set sizes
    (``evict_retraces``).
    """
    rows = np.asarray(rows).reshape(-1)
    if rows.size == 0:
        return list(cache)
    return list(_evict_module(tuple(cache), jnp.asarray(_pad_evict_rows(rows))))


def snapshot_row(layer_cache: dict, row: int) -> dict:
    """Host snapshot of one batch row of a contiguous layer buffer.

    The checkpoint unit of request preemption: every decode-buffer entry
    is batch-leading (attn ``{"k","v"}: (B, span, K, hd)``; SSM
    ``{"h","conv"}``), so one row per layer captures a sequence's full
    recurrent state.  Rows come back as NumPy (host) arrays — checkpoints
    live in host memory while the device slot is recycled.
    """
    return {key: np.asarray(val[row]) for key, val in layer_cache.items()}


def restore_row(layer_cache: dict, row: int, state: dict) -> dict:
    """Write a ``snapshot_row`` checkpoint back into batch row ``row``.

    The inverse of ``snapshot_row`` for contiguous buffers; Mode B paged
    attention restores through ``KVPageTable.insert_rows`` instead (the
    row's frames were freed with the slot)."""
    return {
        key: layer_cache[key].at[row].set(jnp.asarray(state[key]))
        for key in layer_cache
    }


def cache_bytes(cache: List) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache))
