"""KV-cache conversion and (host-)offloaded cache management.

``model.prefill`` returns raw per-layer K/V stacked over layer groups;
decode expects pre-allocated (possibly ring-buffer) caches.  This module
converts between the two, handling sliding-window ring alignment (absolute
position p lives in slot ``p % window``).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def cache_from_prefill(
    cfg: ModelConfig, caches: List, seq_len: int, max_seq: int
) -> List:
    """Convert prefill caches into decode-ready buffers of span ``max_seq``."""
    pattern = model_mod.layer_pattern(cfg)
    out = []
    for j, (kind, _) in enumerate(pattern):
        slot = caches[j]
        if kind != "attn":
            out.append(slot)                       # SSM state passes through
            continue
        k, v = slot["k"], slot["v"]               # (G, B, S, K, hd)
        G, B, S, K, hd = k.shape
        span = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        nk = jnp.zeros((G, B, span, K, hd), k.dtype)
        nv = jnp.zeros_like(nk)
        n = min(S, span)
        if cfg.sliding_window and S > span:
            # ring alignment: token at absolute pos p -> slot p % span
            pos = jnp.arange(S - n, S)
            slots = pos % span
            nk = nk.at[:, :, slots].set(k[:, :, -n:])
            nv = nv.at[:, :, slots].set(v[:, :, -n:])
        else:
            nk = nk.at[:, :, :n].set(k[:, :, -n:])
            nv = nv.at[:, :, :n].set(v[:, :, -n:])
        out.append({"k": nk, "v": nv})
    return out


def cache_bytes(cache: List) -> int:
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache))
