"""Streamed parameter store: weight residency + double-buffered prefetch.

The paper's headline mechanism (Fig. 6; ``S_Expert``/``S_Params`` in
Table 2) is that expert weights live in HOST memory and are streamed
device-ward on an htod channel that hides behind the grouped expert GEMM.
``ParamStore`` is the executor side of that policy:

* the **resident set** is pinned on device, greedily filled up to
  ``Plan.s_params`` by ``core.workload.plan_residency`` — the SAME policy
  the planner's cost model charges misses with, so the planner's predicted
  overlap is measurable against the real engine.  Base weights
  (embedding / final norm / lm_head) are always pinned; sequence mixers and
  norms fill next, expert stacks last.
* the **streamed set** is kept host-side (numpy — the pinned-host analogue
  on this backend) and served through a bounded in-flight window of
  ``prefetch_depth`` per-layer modules (the double buffer ``Plan.s_expert``
  sizes): the engine issues ``prefetch(l+1)`` before launching layer *l*'s
  grouped GEMM, so ``jax.device_put``'s async dispatch overlaps the copy
  with compute; ``acquire(l)`` consumes the in-flight transfer (or fetches
  on demand when prefetch is off — the streamed-serial baseline of the
  ``weight_streaming`` benchmark).

The store keeps device-side accounting (htod bytes at issue time, stall
seconds at acquire time) that ``ModuleBatchingEngine.sync_stats`` folds
into ``EngineStats`` and the scheduler surfaces as ``ServeReport.htod_gb``
/ ``prefetch_wait_s``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis import runtime as sanitizer
from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.models import model as model_mod

# per-layer module split: the streaming granularity.  'mixer' is
# norm1 + attention/SSM; 'ffn' is norm2 + (MoE stacks + router | dense FFN).
_MIXER_KEYS = ("norm1", "attn", "ssm")
_FFN_KEYS = ("norm2", "moe", "ffn")


def unstack_layers(cfg: ModelConfig, params: Dict) -> List[Tuple[str, str, Dict]]:
    """Flatten group-stacked layer params into a per-layer list."""
    pattern = model_mod.layer_pattern(cfg)
    G = model_mod.num_groups(cfg)
    layers = []
    for g in range(G):
        for j, (kind, ffn) in enumerate(pattern):
            slot = jax.tree.map(lambda a: a[g], params["layers"][j])
            layers.append((kind, ffn, slot))
    return layers


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.tree.leaves(tree))


def _to_host(tree):
    return jax.tree.map(np.asarray, tree)


class StreamWindow:
    """Bounded in-flight window of async htod transfers (the double buffer).

    The generic half of the PR 3 streaming design, shared by weight
    streaming (``ParamStore``) and KV-page streaming
    (``serving.cache.KVPageTable``): ``prefetch(key)`` issues the async
    ``jax.device_put`` copy produced by the ``fetch`` closure and parks it
    in a window of at most ``depth`` in-flight entries (oldest evicted);
    ``acquire(key)`` consumes the in-flight transfer — or fetches on
    demand when it was never staged — and accounts the stall seconds spent
    blocking on it.  ``fetch(key) -> (value, nbytes)`` must return the
    device-side value plus the bytes it moved; copies are issued at
    prefetch/fetch time, so ``htod_bytes`` counts issue-side traffic.
    """

    def __init__(self, fetch, depth: int = 2, enabled: bool = True) -> None:
        self._fetch = fetch
        self.depth = max(1, depth)
        self.enabled = enabled
        self.inflight: Dict = {}
        self._order: List = []
        self.htod_bytes = 0
        self.wait_s = 0.0
        self.issued = 0
        self.demand = 0

    def prefetch(self, key) -> None:
        """Stage ``key``'s transfer into the window (async; returns
        immediately).  No-op when disabled or already in flight."""
        if not self.enabled or key in self.inflight:
            return
        while len(self._order) >= self.depth:
            oldest = self._order.pop(0)
            self.inflight.pop(oldest, None)
        with sanitizer.allowed("stream-window"):
            value, nbytes = self._fetch(key)
        self.inflight[key] = value
        self._order.append(key)
        self.htod_bytes += nbytes
        self.issued += 1

    def acquire(self, key):
        """Consume ``key``'s in-flight transfer (or fetch on demand),
        blocking until the copy lands; the stall is accounted in
        ``wait_s``."""
        if key in self.inflight:
            value = self.inflight.pop(key)
            self._order.remove(key)
        else:
            with sanitizer.allowed("stream-window"):
                value, nbytes = self._fetch(key)
            self.htod_bytes += nbytes
            self.demand += 1
        t0 = time.perf_counter()
        jax.block_until_ready(value)
        self.wait_s += time.perf_counter() - t0
        return value

    def take_counters(self) -> Tuple[int, float]:
        """Drain (htod_bytes, wait_s) since the last call."""
        out = (self.htod_bytes, self.wait_s)
        self.htod_bytes = 0
        self.wait_s = 0.0
        return out


class ParamStore:
    """Weight-residency subsystem the engine executes through.

    ``resident_bytes=None`` pins everything on device (the default engine
    behavior — streaming is opt-in).  Any finite budget realizes the greedy
    ``workload.plan_residency`` split; ``resident_bytes=0`` streams every
    per-layer module (base weights stay pinned).

    ``prefetch=True`` is the overlapped mode: ``prefetch(l)`` issues the
    async htod copy of layer *l*'s streamed modules into the in-flight
    window ahead of use.  ``prefetch=False`` fetches on demand at
    ``acquire`` — the serialized copy->compute baseline.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
        prefetch_depth: int = 2,
    ) -> None:
        self.cfg = cfg
        self.prefetch_enabled = prefetch
        self.prefetch_depth = max(1, prefetch_depth)
        self.residency = W.plan_residency(cfg, resident_bytes)
        layers = unstack_layers(cfg, params)
        self.schema: List[Tuple[str, str]] = [(k, f) for k, f, _ in layers]
        # base params: always device-resident (embed / final_norm / lm_head)
        self.base: Dict = {
            k: v for k, v in params.items() if k != "layers"
        }
        # per-layer split into resident (device) and streamed (host) modules
        self._resident: List[Dict[str, Dict]] = []
        self._host: List[Dict[str, Dict]] = []
        for li, (kind, ffn, slot) in enumerate(layers):
            mixer = {k: v for k, v in slot.items() if k in _MIXER_KEYS}
            ffnp = {k: v for k, v in slot.items() if k in _FFN_KEYS}
            res: Dict[str, Dict] = {}
            host: Dict[str, Dict] = {}
            if self.residency.mixer_resident[li]:
                res["mixer"] = mixer
            else:
                host["mixer"] = _to_host(mixer)
            if ffnp:
                if self.residency.ffn_resident[li]:
                    res["ffn"] = ffnp
                else:
                    host["ffn"] = _to_host(ffnp)
            self._resident.append(res)
            self._host.append(host)
        # the double-buffer window: in-flight prefetched layer transfers,
        # bounded at prefetch_depth (shared machinery with KV-page
        # streaming — see StreamWindow)
        self._window = StreamWindow(
            self._fetch, depth=self.prefetch_depth, enabled=True
        )

    @classmethod
    def build(
        cls,
        cfg: ModelConfig,
        params: Dict,
        plan,
        stream_weights: bool = False,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
    ) -> "ParamStore":
        """THE budget-resolution policy, shared by the engine constructor
        and the scheduler: everything resident unless ``stream_weights``;
        the budget is the plan's ``s_params`` unless ``resident_bytes``
        overrides it."""
        budget = None
        if stream_weights:
            budget = plan.s_params if resident_bytes is None else resident_bytes
        return cls(cfg, params, resident_bytes=budget, prefetch=prefetch)

    # -- residency inspection -------------------------------------------
    @property
    def fully_resident(self) -> bool:
        """True when every per-layer module is device-pinned — the
        precondition for the engine's fused decode path (one donated launch
        needs every layer's weights alive on device at once; streamed layers
        keep the per-layer dispatch loop so the htod prefetch has a layer
        boundary to hide behind)."""
        return all(not h for h in self._host)

    def fused_layer_params(self) -> Tuple[Dict, ...]:
        """Per-layer merged param dicts for the fused decode macro-step.

        Only meaningful when ``fully_resident`` — the returned tuple aliases
        the device-pinned arrays (no copies) and is captured once by the
        engine for the lifetime of the store."""
        assert self.fully_resident, "fused params require full residency"
        return tuple(self.acquire(li) for li in range(len(self.schema)))

    def resident_module_bytes(self) -> int:
        return _tree_bytes(self.base) + sum(
            _tree_bytes(m) for res in self._resident for m in res.values()
        )

    def streamed_module_bytes(self) -> int:
        return sum(_tree_bytes(m) for h in self._host for m in h.values())

    def describe(self) -> str:
        return (
            f"resident {self.resident_module_bytes() / 1e9:.3f}GB "
            f"(+{self.residency.n_streamed()} streamed modules, "
            f"{self.streamed_module_bytes() / 1e9:.3f}GB host-side, "
            f"window={self.prefetch_depth}, "
            f"prefetch={'on' if self.prefetch_enabled else 'off'})"
        )

    # -- streaming -------------------------------------------------------
    # window-facing views kept for callers/tests that inspect the store
    @property
    def _inflight(self) -> Dict[int, Dict[str, Dict]]:
        return self._window.inflight

    @property
    def htod_bytes(self) -> int:
        return self._window.htod_bytes

    @property
    def prefetch_wait_s(self) -> float:
        return self._window.wait_s

    @property
    def prefetch_issued(self) -> int:
        return self._window.issued

    @property
    def demand_fetches(self) -> int:
        return self._window.demand

    def _fetch(self, li: int) -> Tuple[Dict[str, Dict], int]:
        """Issue the async htod copy of layer ``li``'s streamed modules."""
        fetched = {
            name: jax.device_put(tree) for name, tree in self._host[li].items()
        }
        nbytes = sum(_tree_bytes(tree) for tree in fetched.values())
        return fetched, nbytes

    def prefetch(self, li: int) -> None:
        """Stage layer ``li``'s streamed modules into the in-flight window
        (async; returns immediately).  Call BEFORE launching the previous
        layer's compute so the copy hides behind it.  Wraps module indices,
        so the last layer prefetches layer 0 for the next decode step."""
        if not self.prefetch_enabled:
            return
        li = li % len(self.schema)
        if not self._host[li]:
            return
        self._window.prefetch(li)

    def acquire(self, li: int) -> Dict:
        """Return layer ``li``'s full param dict with streamed modules on
        device, consuming the in-flight prefetch (or fetching on demand).
        The time spent waiting on the transfer — ideally ~0 when prefetch
        overlapped it with compute — is accounted in ``prefetch_wait_s``."""
        merged: Dict = {}
        for tree in self._resident[li].values():
            merged.update(tree)
        if self._host[li]:
            for tree in self._window.acquire(li).values():
                merged.update(tree)
        return merged

    def take_counters(self) -> Tuple[int, float]:
        """Drain (htod_bytes, prefetch_wait_s) since the last call."""
        return self._window.take_counters()
