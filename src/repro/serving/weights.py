"""Streamed parameter store: weight residency + double-buffered prefetch.

The paper's headline mechanism (Fig. 6; ``S_Expert``/``S_Params`` in
Table 2) is that expert weights live in HOST memory and are streamed
device-ward on an htod channel that hides behind the grouped expert GEMM.
``ParamStore`` is the executor side of that policy:

* the **resident set** is pinned on device, greedily filled up to
  ``Plan.s_params`` by ``core.workload.plan_residency`` — the SAME policy
  the planner's cost model charges misses with, so the planner's predicted
  overlap is measurable against the real engine.  Base weights
  (embedding / final norm / lm_head) are always pinned; sequence mixers and
  norms fill next, expert stacks last.
* the **streamed set** is kept host-side (numpy — the pinned-host analogue
  on this backend) and served through a bounded in-flight window of
  ``prefetch_depth`` per-layer modules (the double buffer ``Plan.s_expert``
  sizes): the engine issues ``prefetch(l+1)`` before launching layer *l*'s
  grouped GEMM, so ``jax.device_put``'s async dispatch overlaps the copy
  with compute; ``acquire(l)`` consumes the in-flight transfer (or fetches
  on demand when prefetch is off — the streamed-serial baseline of the
  ``weight_streaming`` benchmark).

The store keeps device-side accounting (htod bytes at issue time, stall
seconds at acquire time) that ``ModuleBatchingEngine.sync_stats`` folds
into ``EngineStats`` and the scheduler surfaces as ``ServeReport.htod_gb``
/ ``prefetch_wait_s``.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.analysis import runtime as sanitizer
from repro.configs.base import ModelConfig
from repro.core import workload as W
from repro.models import model as model_mod

# per-layer module split: the streaming granularity.  'mixer' is
# norm1 + attention/SSM; 'ffn' is norm2 + (MoE stacks + router | dense FFN).
_MIXER_KEYS = ("norm1", "attn", "ssm")
_FFN_KEYS = ("norm2", "moe", "ffn")


def unstack_layers(cfg: ModelConfig, params: Dict) -> List[Tuple[str, str, Dict]]:
    """Flatten group-stacked layer params into a per-layer list."""
    pattern = model_mod.layer_pattern(cfg)
    G = model_mod.num_groups(cfg)
    layers = []
    for g in range(G):
        for j, (kind, ffn) in enumerate(pattern):
            slot = jax.tree.map(lambda a: a[g], params["layers"][j])
            layers.append((kind, ffn, slot))
    return layers


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.tree.leaves(tree))


def _to_host(tree):
    return jax.tree.map(np.asarray, tree)


class _StalledTransfer:
    """An injected dead in-flight transfer: parked in the window like a
    real value but never becomes ready, so ``acquire`` exercises the
    watchdog recovery path without real wall-clock waiting."""

    def __init__(self, value) -> None:
        self.value = value


class StreamWindow:
    """Bounded in-flight window of async htod transfers (the double buffer).

    The generic half of the PR 3 streaming design, shared by weight
    streaming (``ParamStore``) and KV-page streaming
    (``serving.cache.KVPageTable``): ``prefetch(key)`` issues the async
    ``jax.device_put`` copy produced by the ``fetch`` closure and parks it
    in a window of at most ``depth`` in-flight entries (oldest evicted);
    ``acquire(key)`` consumes the in-flight transfer — or fetches on
    demand when it was never staged — and accounts the stall seconds spent
    blocking on it.  ``fetch(key) -> (value, nbytes)`` must return the
    device-side value plus the bytes it moved; copies are issued at
    prefetch/fetch time, so ``htod_bytes`` counts issue-side traffic.

    ``tag`` names the planned-transfer scope every copy through this window
    is issued under, so the runtime sanitizer can attribute traffic per
    stream (``stream-window`` for whole-module staging, ``expert-prefetch``
    for the predictive per-expert window).

    Fault tolerance: every fetch consults the armed ``faults`` plan (a
    no-op when unarmed) and retries transient failures under the shared
    ``RetryPolicy`` with capped exponential backoff (retried copies run
    in the ``fault-retry`` planned-transfer scope).  With a finite
    ``retry.watchdog_s`` the blocking ``acquire`` wait polls device-buffer
    readiness against a deadline: a dead/stalled in-flight entry is
    abandoned and demand re-fetched once, and only then surfaces as a
    ``StreamTimeoutError`` naming the window tag and key — the historical
    behavior (``watchdog_s=None``) blocked forever.
    """

    def __init__(
        self, fetch, depth: int = 2, enabled: bool = True,
        tag: str = "stream-window", retry: Optional[faults.RetryPolicy] = None,
    ) -> None:
        self._fetch = fetch
        self.tag = tag
        self.depth = max(1, depth)
        self.enabled = enabled
        self.retry = retry if retry is not None else faults.RetryPolicy()
        self.inflight: Dict = {}
        self._order: List = []
        self.htod_bytes = 0
        self.wait_s = 0.0
        self.issued = 0
        self.demand = 0
        self.retries = 0
        self.timeouts = 0

    def _issue(self, key):
        """One fetch attempt, with injected transient failures."""
        fp = faults.current()
        if fp is not None and fp.transfer_fault(self.tag, key):
            raise faults.TransientTransferError(
                f"injected transient transfer fault "
                f"(window {self.tag!r}, key {key!r})")
        return self._fetch(key)

    def _issue_with_retry(self, key, recovery: bool = False):
        """Fetch under the shared retry policy: the first attempt runs in
        this window's planned-transfer scope, retries in ``fault-retry``
        (every attempt of a ``recovery`` re-fetch is retry traffic)."""
        delay = self.retry.backoff_s
        for attempt in range(self.retry.max_retries + 1):
            try:
                scope = ("fault-retry" if recovery or attempt > 0
                         else self.tag)
                with sanitizer.allowed(scope):
                    return self._issue(key)
            except faults.TransientTransferError:
                if attempt >= self.retry.max_retries:
                    raise
                self.retries += 1
                faults.note(f"recovered:transfer-retry:{self.tag}")
                if delay > 0.0:
                    time.sleep(min(delay, self.retry.backoff_cap_s))
                delay = min(delay * 2.0, self.retry.backoff_cap_s or delay)
        raise AssertionError("unreachable")

    def _wait_ready(self, value) -> bool:
        """Block until ``value``'s buffers land; ``False`` on watchdog
        expiry (never with ``watchdog_s=None`` — unbounded wait)."""
        if isinstance(value, _StalledTransfer):
            return False
        if self.retry.watchdog_s is None:
            jax.block_until_ready(value)
            return True
        deadline = time.perf_counter() + self.retry.watchdog_s
        for leaf in jax.tree.leaves(value):
            poll = getattr(leaf, "is_ready", None)
            if poll is None:
                continue
            while not poll():
                if time.perf_counter() >= deadline:
                    return False
                time.sleep(0.0005)
        jax.block_until_ready(
            [x for x in jax.tree.leaves(value) if isinstance(x, jax.Array)])
        return True

    def prefetch(self, key) -> None:
        """Stage ``key``'s transfer into the window (async; returns
        immediately).  No-op when disabled or already in flight."""
        if not self.enabled or key in self.inflight:
            return
        while len(self._order) >= self.depth:
            oldest = self._order.pop(0)
            self.inflight.pop(oldest, None)
        value, nbytes = self._issue_with_retry(key)
        fp = faults.current()
        if fp is not None and fp.stall_fault(self.tag, key):
            value = _StalledTransfer(value)
        self.inflight[key] = value
        self._order.append(key)
        self.htod_bytes += nbytes
        self.issued += 1

    def acquire(self, key):
        """Consume ``key``'s in-flight transfer (or fetch on demand),
        blocking until the copy lands; the stall is accounted in
        ``wait_s``.  A wait that exceeds ``retry.watchdog_s`` (or an
        injected stalled transfer) is recovered by abandoning the dead
        entry and demand re-fetching once; a second expiry raises
        ``StreamTimeoutError`` with the window tag and key."""
        if key in self.inflight:
            value = self.inflight.pop(key)
            self._order.remove(key)
        else:
            value, nbytes = self._issue_with_retry(key)
            self.htod_bytes += nbytes
            self.demand += 1
        t0 = time.perf_counter()
        ok = self._wait_ready(value)
        self.wait_s += time.perf_counter() - t0
        if ok:
            return value
        self.timeouts += 1
        faults.note(f"recovered:transfer-timeout:{self.tag}")
        try:
            value, nbytes = self._issue_with_retry(key, recovery=True)
        except faults.TransientTransferError as e:
            raise faults.StreamTimeoutError(
                f"stalled stream transfer and the recovery fetch failed "
                f"after {self.retry.max_retries} retries "
                f"(window {self.tag!r}, key {key!r})") from e
        self.htod_bytes += nbytes
        self.demand += 1
        t0 = time.perf_counter()
        ok = self._wait_ready(value)
        self.wait_s += time.perf_counter() - t0
        if not ok:
            raise faults.StreamTimeoutError(
                f"stream transfer stalled twice (watchdog "
                f"{self.retry.watchdog_s}s; window {self.tag!r}, "
                f"key {key!r})")
        return value

    def take_counters(self) -> Tuple[int, float]:
        """Drain (htod_bytes, wait_s) since the last call."""
        out = (self.htod_bytes, self.wait_s)
        self.htod_bytes = 0
        self.wait_s = 0.0
        return out

    def take_fault_counters(self) -> Tuple[int, int]:
        """Drain (retries, timeouts) since the last call."""
        out = (self.retries, self.timeouts)
        self.retries = 0
        self.timeouts = 0
        return out


class ParamStore:
    """Weight-residency subsystem the engine executes through.

    ``resident_bytes=None`` pins everything on device (the default engine
    behavior — streaming is opt-in).  Any finite budget realizes the greedy
    ``workload.plan_residency`` split; ``resident_bytes=0`` streams every
    per-layer module (base weights stay pinned).

    ``prefetch=True`` is the overlapped mode: ``prefetch(l)`` issues the
    async htod copy of layer *l*'s streamed modules into the in-flight
    window ahead of use.  ``prefetch=False`` fetches on demand at
    ``acquire`` — the serialized copy->compute baseline.

    ``predict_topk > 0`` switches streamed MoE layers to PREDICTIVE
    PER-EXPERT streaming: the expert stacks are split into per-expert host
    handles served through a second ``StreamWindow`` (planned-transfer tag
    ``expert-prefetch``), while the layer's norm2 + router — tiny, and the
    router is needed on device to predict the NEXT layer's expert set —
    stay pinned.  ``prefetch_experts(l+1, predicted)`` stages only the
    predicted set; ``acquire_experts(l, used)`` assembles the grouped-GEMM
    stacks from the in-flight set, the hot-expert LRU, and on-demand
    fetches for mispredictions — prediction moves WHEN bytes move, never
    WHICH math runs.  ``lru_bytes`` (default: the residency plan's spare
    bytes) bounds a device-side hot-expert LRU: every expert use promotes
    its weights; cold experts are demoted when the byte budget overflows.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
        prefetch_depth: int = 2,
        predict_topk: int = 0,
        lru_bytes: Optional[float] = None,
    ) -> None:
        self.cfg = cfg
        self.prefetch_enabled = prefetch
        self.prefetch_depth = max(1, prefetch_depth)
        self.residency = W.plan_residency(cfg, resident_bytes)
        self.predict_topk = (
            max(0, min(cfg.num_experts, int(predict_topk)))
            if cfg.has_moe else 0
        )
        layers = unstack_layers(cfg, params)
        self.schema: List[Tuple[str, str]] = [(k, f) for k, f, _ in layers]
        # base params: always device-resident (embed / final_norm / lm_head)
        self.base: Dict = {
            k: v for k, v in params.items() if k != "layers"
        }
        # per-layer split into resident (device) and streamed (host) modules
        self._resident: List[Dict[str, Dict]] = []
        self._host: List[Dict[str, Dict]] = []
        # predictive split of streamed MoE layers: norm2 + router pinned
        # device-side (keyed by layer), expert stacks host-side as
        # per-expert slices (numpy views — zero-copy)
        self._moe_shared: Dict[int, Dict] = {}
        self._experts_host: Dict[int, Dict[str, np.ndarray]] = {}
        for li, (kind, ffn, slot) in enumerate(layers):
            mixer = {k: v for k, v in slot.items() if k in _MIXER_KEYS}
            ffnp = {k: v for k, v in slot.items() if k in _FFN_KEYS}
            res: Dict[str, Dict] = {}
            host: Dict[str, Dict] = {}
            if self.residency.mixer_resident[li]:
                res["mixer"] = mixer
            else:
                host["mixer"] = _to_host(mixer)
            if ffnp:
                if self.residency.ffn_resident[li]:
                    res["ffn"] = ffnp
                elif self.predict_topk > 0 and ffn == "moe":
                    self._moe_shared[li] = {
                        "norm2": jax.device_put(ffnp["norm2"]),
                        "router": jax.device_put(ffnp["moe"]["router"]),
                    }
                    self._experts_host[li] = {
                        k: np.asarray(ffnp["moe"][k])
                        for k in ("experts_w_gate", "experts_w_up",
                                  "experts_w_down")
                    }
                else:
                    host["ffn"] = _to_host(ffnp)
            self._resident.append(res)
            self._host.append(host)
        # the double-buffer window: in-flight prefetched layer transfers,
        # bounded at prefetch_depth (shared machinery with KV-page
        # streaming — see StreamWindow)
        self._window = StreamWindow(
            self._fetch, depth=self.prefetch_depth, enabled=True
        )
        # predictive per-expert window: keys are (layer, expert).  Depth
        # covers two layers' worth of whole stacks so prefill's all-expert
        # staging and back-to-back predicted sets never thrash each other.
        self._expert_window = StreamWindow(
            self._fetch_expert,
            depth=2 * max(1, cfg.num_experts),
            enabled=True,
            tag="expert-prefetch",
        )
        # hot-expert LRU: (layer, expert) -> (device tree, nbytes).  Usage
        # promotes (move-to-end); overflow demotes the coldest entry.  The
        # byte budget defaults to whatever the greedy residency fill left
        # unused — bytes the planner already reserved for weights.
        self._lru: "OrderedDict[Tuple[int, int], Tuple[Tuple, int]]" = (
            OrderedDict()
        )
        self.lru_bytes = float(
            self.residency.spare_bytes if lru_bytes is None else lru_bytes
        )
        self._lru_used = 0
        self._expert_counters = {
            "pred_hits": 0, "pred_misses": 0, "lru_hits": 0,
        }
        # zeros filler for experts with no routed tokens this step: an
        # unrouted expert's grouped-GEMM rows are all-zero inputs whose
        # outputs are never gathered back, so substituting zero weights is
        # bit-identical (zeros — NOT uninitialized memory — so no NaNs
        # propagate through the masked-out rows).  Built EAGERLY: the first
        # acquire_experts happens inside a decode region where allocating
        # would trip the transfer guard.
        self._zero_expert: Optional[Tuple] = None
        if self._experts_host:
            self._zeros_expert()

    @classmethod
    def build(
        cls,
        cfg: ModelConfig,
        params: Dict,
        plan,
        stream_weights: bool = False,
        resident_bytes: Optional[float] = None,
        prefetch: bool = True,
        predict_topk: Optional[int] = None,
        lru_bytes: Optional[float] = None,
    ) -> "ParamStore":
        """THE budget-resolution policy, shared by the engine constructor
        and the scheduler: everything resident unless ``stream_weights``;
        the budget is the plan's ``s_params`` unless ``resident_bytes``
        overrides it.  Predictive per-expert streaming follows the plan's
        ``predict_topk`` unless overridden."""
        budget = None
        khat = 0
        if stream_weights:
            budget = plan.s_params if resident_bytes is None else resident_bytes
            khat = (
                getattr(plan, "predict_topk", 0)
                if predict_topk is None else predict_topk
            )
        return cls(
            cfg, params, resident_bytes=budget, prefetch=prefetch,
            predict_topk=khat, lru_bytes=lru_bytes,
        )

    # -- residency inspection -------------------------------------------
    @property
    def fully_resident(self) -> bool:
        """True when every per-layer module is device-pinned — the
        precondition for the engine's fused decode path (one donated launch
        needs every layer's weights alive on device at once; streamed layers
        keep the per-layer dispatch loop so the htod prefetch has a layer
        boundary to hide behind)."""
        return all(not h for h in self._host) and not self._experts_host

    def fused_layer_params(self) -> Tuple[Dict, ...]:
        """Per-layer merged param dicts for the fused decode macro-step.

        Only meaningful when ``fully_resident`` — the returned tuple aliases
        the device-pinned arrays (no copies) and is captured once by the
        engine for the lifetime of the store."""
        assert self.fully_resident, "fused params require full residency"
        return tuple(self.acquire(li) for li in range(len(self.schema)))

    def resident_module_bytes(self) -> int:
        return (
            _tree_bytes(self.base)
            + sum(_tree_bytes(m) for res in self._resident
                  for m in res.values())
            + sum(_tree_bytes(m) for m in self._moe_shared.values())
        )

    def streamed_module_bytes(self) -> int:
        return (
            sum(_tree_bytes(m) for h in self._host for m in h.values())
            + sum(_tree_bytes(m) for m in self._experts_host.values())
        )

    def describe(self) -> str:
        pred = (
            f", predict_topk={self.predict_topk}, "
            f"lru={self.lru_bytes / 1e9:.3f}GB"
            if self.predict_topk > 0 else ""
        )
        return (
            f"resident {self.resident_module_bytes() / 1e9:.3f}GB "
            f"(+{self.residency.n_streamed()} streamed modules, "
            f"{self.streamed_module_bytes() / 1e9:.3f}GB host-side, "
            f"window={self.prefetch_depth}, "
            f"prefetch={'on' if self.prefetch_enabled else 'off'}{pred})"
        )

    # -- streaming -------------------------------------------------------
    # window-facing views kept for callers/tests that inspect the store
    @property
    def _inflight(self) -> Dict[int, Dict[str, Dict]]:
        return self._window.inflight

    @property
    def htod_bytes(self) -> int:
        return self._window.htod_bytes + self._expert_window.htod_bytes

    @property
    def prefetch_wait_s(self) -> float:
        return self._window.wait_s + self._expert_window.wait_s

    @property
    def prefetch_issued(self) -> int:
        return self._window.issued + self._expert_window.issued

    @property
    def demand_fetches(self) -> int:
        return self._window.demand + self._expert_window.demand

    def _fetch(self, li: int) -> Tuple[Dict[str, Dict], int]:
        """Issue the async htod copy of layer ``li``'s streamed modules."""
        fetched = {
            name: jax.device_put(tree) for name, tree in self._host[li].items()
        }
        nbytes = sum(_tree_bytes(tree) for tree in fetched.values())
        return fetched, nbytes

    def prefetch(self, li: int) -> None:
        """Stage layer ``li``'s streamed modules into the in-flight window
        (async; returns immediately).  Call BEFORE launching the previous
        layer's compute so the copy hides behind it.  Wraps module indices,
        so the last layer prefetches layer 0 for the next decode step."""
        if not self.prefetch_enabled:
            return
        li = li % len(self.schema)
        if not self._host[li]:
            return
        self._window.prefetch(li)

    def acquire(self, li: int, experts: bool = True) -> Dict:
        """Return layer ``li``'s full param dict with streamed modules on
        device, consuming the in-flight prefetch (or fetching on demand).
        The time spent waiting on the transfer — ideally ~0 when prefetch
        overlapped it with compute — is accounted in ``prefetch_wait_s``.

        For predictive-streamed MoE layers, ``experts=False`` returns only
        the mixer + pinned norm2/router — the decode hot path assembles the
        expert stacks itself via ``acquire_experts`` after reading back the
        routed set.  ``experts=True`` (prefill, loop oracle) assembles the
        FULL expert stack, bit-identical to whole-stack streaming."""
        merged: Dict = {}
        for tree in self._resident[li].values():
            merged.update(tree)
        if self._host[li]:
            for tree in self._window.acquire(li).values():
                merged.update(tree)
        if li in self._moe_shared:
            shared = self._moe_shared[li]
            merged["norm2"] = shared["norm2"]
            moe: Dict = {"router": shared["router"]}
            if experts:
                wg, wu, wd = self.acquire_experts(
                    li, range(self.cfg.num_experts), record=False
                )
                moe["experts_w_gate"] = wg
                moe["experts_w_up"] = wu
                moe["experts_w_down"] = wd
            merged["moe"] = moe
        return merged

    # -- predictive per-expert streaming --------------------------------
    def streams_experts(self, li: int) -> bool:
        """True when layer ``li``'s expert stacks stream per-expert (the
        predictive decode stage applies)."""
        return li % len(self.schema) in self._experts_host

    def moe_shared(self, li: int) -> Dict:
        """Device-pinned norm2 + router of a predictive-streamed MoE layer
        — the router is what lets layer *l* predict layer *l+1*'s experts
        without waiting for *l+1*'s weights."""
        return self._moe_shared[li % len(self.schema)]

    def _fetch_expert(self, key: Tuple[int, int]) -> Tuple[Tuple, int]:
        """Issue the async htod copy of ONE expert's weight slices."""
        li, e = key
        host = self._experts_host[li]
        tree = tuple(
            jax.device_put(host[k][e])
            for k in ("experts_w_gate", "experts_w_up", "experts_w_down")
        )
        return tree, _tree_bytes(tree)

    def _zeros_expert(self) -> Tuple:
        """Cached zero-weight filler for experts with no routed tokens.
        Zero weights are exact for unrouted experts (their buffer rows are
        never gathered back) and, unlike uninitialized memory, cannot leak
        NaNs through the masked scatter."""
        if self._zero_expert is None:
            host = next(iter(self._experts_host.values()))
            self._zero_expert = tuple(
                jnp.zeros(host[k].shape[1:], dtype=host[k].dtype)
                for k in ("experts_w_gate", "experts_w_up", "experts_w_down")
            )
        return self._zero_expert

    def _lru_get(self, key: Tuple[int, int]) -> Optional[Tuple]:
        hit = self._lru.get(key)
        if hit is None:
            return None
        self._lru.move_to_end(key)
        return hit[0]

    def _lru_put(self, key: Tuple[int, int], tree: Tuple, nbytes: int) -> None:
        """Promote a just-used expert into the hot-expert LRU; demote the
        coldest entries past the byte budget.  Promotion on every use makes
        residency track measured routing frequency: hot experts stay, cold
        ones age out."""
        if nbytes > self.lru_bytes:
            return
        if key in self._lru:
            self._lru.move_to_end(key)
            return
        self._lru[key] = (tree, nbytes)
        self._lru_used += nbytes
        while self._lru_used > self.lru_bytes and self._lru:
            _, (_, old_bytes) = self._lru.popitem(last=False)
            self._lru_used -= old_bytes

    def prefetch_experts(self, li: int, expert_ids: Iterable[int]) -> None:
        """Stage the PREDICTED expert set for layer ``li`` into the
        expert window (async).  Experts already hot in the LRU skip the
        copy entirely — that is the LRU paying for itself."""
        if not self.prefetch_enabled:
            return
        li = li % len(self.schema)
        if li not in self._experts_host:
            return
        E = self.cfg.num_experts
        for e in expert_ids:
            e = int(e)
            if not 0 <= e < E or (li, e) in self._lru:
                continue
            self._expert_window.prefetch((li, e))

    def acquire_experts(
        self, li: int, expert_ids: Iterable[int], record: bool = True
    ) -> Tuple:
        """Assemble layer ``li``'s grouped-GEMM weight stacks (E, ...) with
        true weights for ``expert_ids`` and the zeros filler elsewhere.

        Source order per expert: hot-expert LRU -> in-flight predicted
        prefetch -> on-demand fetch (the guaranteed-correct misprediction
        fallback).  ``record=True`` (the decode stage) counts
        prediction/LRU hit accounting; prefill's all-expert assembly passes
        ``record=False`` so it cannot dilute the decode hit rate."""
        li = li % len(self.schema)
        want = {int(e) for e in expert_ids}
        zeros = self._zeros_expert()
        cols: List[Tuple] = []
        for e in range(self.cfg.num_experts):
            if e not in want:
                cols.append(zeros)
                continue
            key = (li, e)
            tree = self._lru_get(key)
            if tree is not None:
                if record:
                    self._expert_counters["lru_hits"] += 1
                cols.append(tree)
                continue
            staged = key in self._expert_window.inflight
            if record:
                which = "pred_hits" if staged else "pred_misses"
                self._expert_counters[which] += 1
            tree = self._expert_window.acquire(key)
            self._lru_put(key, tree, _tree_bytes(tree))
            cols.append(tree)
        return tuple(jnp.stack([c[i] for c in cols]) for i in range(3))

    def take_counters(self) -> Tuple[int, float]:
        """Drain (htod_bytes, prefetch_wait_s) since the last call —
        summed over the whole-module and per-expert windows."""
        b1, w1 = self._window.take_counters()
        b2, w2 = self._expert_window.take_counters()
        return b1 + b2, w1 + w2

    def take_fault_counters(self) -> Tuple[int, int]:
        """Drain (transfer retries, watchdog timeouts) since the last call
        — summed over the whole-module and per-expert windows."""
        r1, t1 = self._window.take_fault_counters()
        r2, t2 = self._expert_window.take_fault_counters()
        return r1 + r2, t1 + t2

    def take_expert_counters(self) -> Dict[str, int]:
        """Drain predictive-streaming hit counters since the last call:
        ``pred_hits`` (expert was staged by prediction), ``pred_misses``
        (demand-fetched mispredictions/cold starts), ``lru_hits`` (served
        from the hot-expert LRU, no copy at all)."""
        out = dict(self._expert_counters)
        out["lru_bytes_used"] = int(self._lru_used)
        for k in self._expert_counters:
            self._expert_counters[k] = 0
        return out
