"""Token sampling: per-request params, batched per-slot device-side sampling.

The engine's default decoding strategy is greedy argmax (paper §B); online
serving needs per-request sampling — a batch may mix greedy slots with
seeded temperature / top-k slots.  ``SamplingParams`` is the per-request
policy, ``BatchSampler`` holds one slot of sampling state per engine batch
row and turns a ``(B, V)`` logits array into ``(B,)`` next tokens in a
single jitted launch (``_sample_module``): per-slot Gumbel-max over
temperature-scaled, top-k-masked logits, greedy slots taking the plain
argmax.

Determinism contract: slot *i*'s token at its *t*-th generated position is
a pure function of ``(logits, PRNGKey(seed), t)`` — the key is folded with
the per-request token index, not any global step counter, so the same
request produces the same stream under the static and the continuous
scheduler, across runs, and regardless of which batch slot it lands in.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import runtime as sanitizer
from repro.analysis.registry import register_jit


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    ``temperature <= 0`` means greedy (argmax) — identical to the engine's
    default.  ``top_k > 0`` restricts sampling to the k highest logits.
    ``seed`` determines the request's whole token stream (see the module
    determinism contract).
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1)


def temperature_sample(key, logits: jax.Array, temperature: float = 1.0):
    if temperature <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def top_k_sample(key, logits: jax.Array, k: int, temperature: float = 1.0):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / max(temperature, 1e-6), axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0]


def sample_tokens(logits, keys, steps, temps, topks, use_topk):
    """Traceable batched sampling math: (B, V) logits -> (B,) tokens.

    This is THE per-slot sampling function — ``BatchSampler`` launches it as
    its own jitted module (``_sample_module``) and the engine's fused decode
    macro-step inlines it inside the one-launch chunk, so both paths share
    bit-identical sampling (the fused/per-module token-identity contract
    depends on this being the single implementation).

    Per-slot Gumbel-max categorical over temperature-scaled logits with an
    optional top-k mask; slots with ``temps <= 0`` take the greedy argmax
    (on the raw logits, so a greedy slot is bit-identical to
    ``jnp.argmax``).  ``keys`` are per-slot base PRNG keys folded with
    ``steps`` (the slot's token index), which is what makes a request's
    stream independent of scheduler, slot and batch composition.
    ``use_topk=False`` (static, set by the caller when no selected slot has
    ``top_k > 0``) skips the O(B*V log V) vocab sort the kth-threshold
    needs — pure-temperature slots sample identically either way, since
    their ``(k > 0)`` mask discards the threshold.
    """
    B, V = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1)
    lg = logits.astype(jnp.float32)
    if use_topk:
        k = jnp.clip(topks, 0, V)
        sorted_desc = -jnp.sort(-lg, axis=-1)
        kth = jnp.take_along_axis(
            sorted_desc, (jnp.maximum(k, 1) - 1)[:, None], axis=-1
        )                                                   # (B, 1)
        lg = jnp.where((k[:, None] > 0) & (lg < kth), -jnp.inf, lg)
    scaled = lg / jnp.maximum(temps, 1e-6)[:, None]

    def noise(key, step):
        return jax.random.gumbel(jax.random.fold_in(key, step), (V,),
                                 jnp.float32)

    gum = jax.vmap(noise)(keys, steps)
    sampled = jnp.argmax(scaled + gum, axis=-1)
    return jnp.where(temps > 0, sampled, greedy_tok)


_sample_module = register_jit("sampling.sample")(
    functools.partial(jax.jit, static_argnames=("use_topk",))(sample_tokens)
)


class BatchSampler:
    """Per-slot sampling state for one engine batch.

    The scheduler sets a slot's ``SamplingParams`` at admission
    (``set_slot``), clears it at eviction (``clear_slot``; cleared slots
    are greedy no-ops), and calls ``sample`` once per logits column —
    each call advances the sampled slots' token indices by one.  When
    every selected slot is greedy the call is a plain ``jnp.argmax`` (no
    keys materialized, no extra launch).
    """

    def __init__(self, nslots: int) -> None:
        self.nslots = nslots
        self._keys = np.zeros((nslots, 2), np.uint32)
        self._steps = np.zeros(nslots, np.int32)
        self._temps = np.zeros(nslots, np.float32)
        self._topks = np.zeros(nslots, np.int32)

    def set_slot(self, i: int, params: Optional[SamplingParams],
                 salt: Optional[int] = None) -> None:
        """Arm slot ``i`` with ``params`` (None = greedy), resetting its
        token index.  ``salt`` (when given) is folded into the base key —
        used by uniform batch APIs to decorrelate rows sharing one seed."""
        sp = params or GREEDY
        key = jax.random.PRNGKey(sp.seed)
        if salt is not None:
            key = jax.random.fold_in(key, salt)
        self._keys[i] = np.asarray(key, np.uint32)
        self._steps[i] = 0
        self._temps[i] = max(0.0, float(sp.temperature))
        self._topks[i] = int(sp.top_k)

    def clear_slot(self, i: int) -> None:
        self._keys[i] = 0
        self._steps[i] = 0
        self._temps[i] = 0.0
        self._topks[i] = 0

    @classmethod
    def uniform(cls, nslots: int,
                params: Optional[SamplingParams]) -> "BatchSampler":
        """One shared policy for every slot, with the row index folded into
        each slot's key so rows sharing a seed draw independent streams."""
        s = cls(nslots)
        if params is not None:
            for i in range(nslots):
                s.set_slot(i, params, salt=i)
        return s

    def state(self, slots: Sequence[int]):
        """The selected slots' raw sampling state ``(keys, steps, temps,
        topks)`` — consumed by the engine's fused decode chunk, which inlines
        ``sample_tokens`` on device and advances the slots with
        ``advance()`` afterwards."""
        idx = np.asarray(slots, np.int64)
        return (self._keys[idx].copy(), self._steps[idx].copy(),
                self._temps[idx].copy(), self._topks[idx].copy())

    def advance(self, slots: Sequence[int], n: int = 1) -> None:
        """Advance the selected slots' token indices by ``n`` (the fused
        chunk sampled ``n`` tokens per slot device-side)."""
        self._steps[np.asarray(slots, np.int64)] += n

    def sample(self, logits: jax.Array,
               slots: Optional[Sequence[int]] = None) -> jax.Array:
        """Next token for each selected slot: (n, V) logits -> (n,) tokens,
        row j of ``logits`` belonging to ``slots[j]`` (default: all)."""
        idx = (np.arange(self.nslots) if slots is None
               else np.asarray(slots, np.int64))
        assert logits.shape[0] == idx.size, (logits.shape, idx.size)
        if not (self._temps[idx] > 0).any():
            self._steps[idx] += 1
            return jnp.argmax(logits, axis=-1)
        with sanitizer.allowed("sampler-state"):
            toks = _sample_module(
                logits,
                jnp.asarray(self._keys[idx]),
                jnp.asarray(self._steps[idx]),
                jnp.asarray(self._temps[idx]),
                jnp.asarray(self._topks[idx]),
                use_topk=bool((self._topks[idx] > 0).any()),
            )
        self._steps[idx] += 1
        return toks
