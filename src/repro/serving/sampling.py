"""Token sampling strategies (the engine itself is greedy, paper §B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1)


def temperature_sample(key, logits: jax.Array, temperature: float = 1.0):
    if temperature <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def top_k_sample(key, logits: jax.Array, k: int, temperature: float = 1.0):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / max(temperature, 1e-6), axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0]
