"""Open-loop arrival processes for online serving.

The offline protocol drains a fixed queue (every request due at t=0); an
online workload is open-loop — request *i* becomes admissible only at its
``arrival_s`` offset on the server's virtual clock (which is keyed off wall
time from the first ``Server.step``).  This module generates arrival-time
vectors and stamps them onto requests:

* ``poisson(n, rate)``   — exponential inter-arrival gaps (the standard
  open-loop load model vLLM/Ollama-style serving benchmarks use);
* ``uniform(n, gap)``    — a fixed-gap trace;
* ``trace([...])``       — an explicit offset list (validated);
* ``assign(requests, t)``— stamp ``arrival_s`` onto a request list.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def poisson(n: int, rate: float, seed: int = 0,
            start: float = 0.0) -> np.ndarray:
    """``n`` arrival offsets (seconds) of a Poisson process at ``rate``
    requests/second, starting at ``start``.  Deterministic in ``seed``."""
    if rate <= 0:
        raise ValueError(f"Poisson arrival rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return start + np.cumsum(gaps)


def uniform(n: int, gap: float, start: float = 0.0) -> np.ndarray:
    """``n`` arrivals a fixed ``gap`` seconds apart (first at ``start``)."""
    return start + gap * np.arange(n, dtype=np.float64)


def trace(times: Sequence[float]) -> np.ndarray:
    """Validate an explicit arrival trace: finite, non-negative offsets."""
    t = np.asarray(list(times), np.float64)
    if t.size and (not np.isfinite(t).all() or (t < 0).any()):
        raise ValueError(f"arrival trace must be finite and >= 0, got {t}")
    return t


def assign(requests: List, times: Sequence[float]) -> List:
    """Stamp ``times[i]`` onto ``requests[i].arrival_s`` (in place).

    Returns the request list for chaining.  Raises when the trace is
    shorter than the request list (a silently-cycled arrival trace would
    fabricate load)."""
    t = trace(times)
    if len(requests) > t.size:
        raise ValueError(
            f"arrival trace has {t.size} entries for {len(requests)} requests"
        )
    for r, s in zip(requests, t):
        r.arrival_s = float(s)
    return requests
