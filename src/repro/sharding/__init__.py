from repro.sharding.specs import ShardCtx, param_shardings

__all__ = ["ShardCtx", "param_shardings"]
