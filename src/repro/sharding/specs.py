"""Logical-axis sharding rules.

``ShardCtx`` carries the physical mesh plus the mapping from the two logical
axes the model code uses — ``'batch'`` (data parallel, possibly spanning the
``pod`` axis) and ``'model'`` (tensor/expert parallel) — to mesh axis names.
All model code expresses shardings in logical terms; a ``ShardCtx()`` with no
mesh turns every annotation into a no-op so the same code runs on one CPU
device in tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map only exists as a top-level name from jax 0.6; on the pinned
# 0.4.x line fall back to the experimental home, where the replication-check
# kwarg is still called check_rep (renamed to check_vma upstream).
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def shard_map(f, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_04x(f, **kwargs)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


@dataclass(frozen=True)
class ShardCtx:
    """Mesh context threaded through model code.  Hashable and static."""

    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ()      # e.g. ('data',) or ('pod', 'data')
    model_axis: Optional[str] = None      # e.g. 'model'
    seq_shard: bool = False               # sequence-parallel residual stream
    # MoE execution path (see models/moe.py): 'psum' | 'a2a' pick the
    # expert-parallel collective on a mesh; 'grouped' selects the
    # single-device capacity-bucketed grouped dispatch (the engine's path).
    moe_dispatch: str = "psum"
    # per-expert capacity override for the grouped path (None: the
    # capacity_factor-based default).  The engine's grouped prefill sets
    # this to the micro-batch token count so no routed copy is dropped.
    moe_capacity: Optional[int] = None

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        if self.mesh is None:
            return 1
        return _axis_size(self.mesh, self.batch_axes)

    def resolve(self, logical) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes or None
        if logical == "model":
            return (self.model_axis,) if self.model_axis else None
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical_axes, shape: Sequence[int] | None = None) -> P:
        """PartitionSpec from logical per-dim axes, dropping non-divisible dims."""
        out = []
        for i, la in enumerate(logical_axes):
            phys = self.resolve(la)
            if phys is not None and shape is not None:
                size = _axis_size(self.mesh, phys)
                if shape[i] % size != 0:
                    phys = None
            out.append(phys if phys is None else tuple(phys))
        # PartitionSpec wants strings or tuples
        cleaned = [a[0] if (a is not None and len(a) == 1) else a for a in out]
        return P(*cleaned)

    def shard(self, x: jax.Array, *logical_axes) -> jax.Array:
        """with_sharding_constraint in logical axes; no-op without a mesh."""
        if self.mesh is None:
            return x
        spec = self.spec(*logical_axes, shape=x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def shard_residual(self, x: jax.Array) -> jax.Array:
        """Residual stream (B, S, D): optionally sequence-parallel over the
        model axis (Megatron-SP style) to bound per-device activation
        memory in deep-model training."""
        if self.seq_shard:
            return self.shard(x, "batch", "model", None)
        return self.shard(x, "batch", None, None)

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------
def _rule_for(path: str, shape: Tuple[int, ...], zero1: bool) -> Tuple:
    """Return logical axes per dim for a parameter identified by its path.

    ``zero1`` additionally shards a replicated large dim over 'batch'
    (ZeRO-1 style) — used for training so optimizer state is partitioned.
    """
    d = None  # replicated marker
    data = "batch" if zero1 else None

    def dims(*axes):
        return tuple(axes)

    if len(shape) == 0 or "norm" in path or path.endswith("scale") or path.endswith("bias_norm"):
        return dims(*([d] * len(shape)))
    # MoE expert stacks: (E, in, out) — expert parallelism on dim 0
    if "experts" in path and len(shape) == 3:
        if "w_down" in path:
            return dims("model", d, data)
        return dims("model", data, d)
    if "router" in path:
        return dims(data, d)[: len(shape)]
    if "embed" in path:
        return dims(d, "model")          # (V, D): shard D
    if "lm_head" in path:
        return dims(data, "model")       # (D, V): shard V
    # attention projections
    if any(k in path for k in ("wq", "wk", "wv")):
        if len(shape) == 1:              # bias (H*hd,)
            return dims("model")
        return dims(data, "model")       # (D, H*hd)
    if "wo" in path:
        return dims("model", data)       # (H*hd, D)
    # dense FFN
    if any(k in path for k in ("w_gate", "w_up")):
        return dims(data, "model")
    if "w_down" in path:
        return dims("model", data)
    # SSM projections
    if any(k in path for k in ("wz", "wx", "wB", "wC", "wdt", "in_proj")):
        return dims(data, "model")[: len(shape)]
    if "out_proj" in path:
        return dims("model", data)
    if "conv" in path:
        return dims(d, "model")[: len(shape)]  # (width, channels)
    if path.endswith("A_log") or path.endswith("D") or path.endswith("dt_bias"):
        return dims("model")[: len(shape)]
    return dims(*([d] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(ctx: ShardCtx, params, *, zero1: bool = False, stacked_dims: int = 1):
    """Tree of NamedShardings (or None without mesh) for a param pytree.

    ``stacked_dims`` — number of leading scan-stacking dims (layer groups)
    that are never sharded.
    """

    def one(path, leaf):
        if ctx.mesh is None:
            return None
        pstr = _path_str(path)
        shape = leaf.shape
        # only the per-layer stack ('layers/...') carries leading group dims
        n_lead = stacked_dims if pstr.startswith("layers") else 0
        n_lead = min(n_lead, max(0, len(shape) - 1))
        core_shape = shape[n_lead:]
        logical = _rule_for(pstr, core_shape, zero1)
        # expert count not divisible by the model axis => tensor-parallel
        # experts instead of expert parallelism (shard the hidden dim)
        if (
            "experts" in pstr
            and len(core_shape) == 3
            and core_shape[0] % max(ctx.model_size, 1) != 0
        ):
            if "w_down" in pstr:
                logical = (None, "model", "batch" if zero1 else None)
            else:
                logical = (None, "batch" if zero1 else None, "model")
        logical = tuple([None] * n_lead) + tuple(logical)
        spec = ctx.spec(*logical, shape=shape)
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def cache_shardings(ctx: ShardCtx, cache):
    """Shardings for decode caches.

    KV leaves (G, B, S, K, hd): batch over data; KV heads over model when
    divisible, else head_dim over model.  SSM state (G, B, nh, ns, hp):
    heads over model.  Conv state (G, B, W, ch): channels over model.
    """

    def one(path, leaf):
        if ctx.mesh is None:
            return None
        name = _path_str(path)
        shape = leaf.shape
        msize = max(ctx.model_size, 1)
        if name.endswith("conv"):
            logical = (None, "batch", None, "model")
        elif name.endswith("k") or name.endswith("v"):
            if shape[3] % msize == 0:
                # KV heads shard over the model axis
                logical = (None, "batch", None, "model", None)
            elif shape[2] % msize == 0:
                # context parallelism: cache sequence over the model axis
                logical = (None, "batch", "model", None, None)
            else:
                logical = (None, "batch", None, None, "model")
        elif name.endswith("h"):
            logical = (None, "batch", "model", None, None)
        else:
            logical = tuple([None] * len(shape))
        spec = ctx.spec(*logical, shape=shape)
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache)
