"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle, allclose + time.

On CPU the Pallas interpreter is orders of magnitude slower than XLA (it
executes the kernel body in Python) — the timing column here verifies the
harness, not TPU performance; correctness is the contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Table, fmt, timed
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def kernel_allclose() -> Table:
    t = Table("kernels", ["kernel", "shape", "oracle_us", "maxdiff"])

    # expert_ffn
    E, C, D, F = 4, 256, 256, 256
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, D)) * 0.3).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[1], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[2], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[3], (E, F, D)) * 0.05).astype(jnp.bfloat16)
    t_us, want = timed(jax.jit(ref.expert_ffn_ref), x, wg, wu, wd)
    got = ops.expert_ffn(x, wg, wu, wd, interpret=True)
    d = float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                              want.astype(jnp.float32))))
    t.add("expert_ffn", f"{E}x{C}x{D}x{F}", fmt(t_us * 1e6), f"{d:.2e}")

    # decode attention
    B, H, K, hd, S = 4, 8, 2, 64, 1024
    q = jax.random.normal(ks[0], (B, H, hd))
    kk = jax.random.normal(ks[1], (B, S, K, hd))
    vv = jax.random.normal(ks[2], (B, S, K, hd))
    t_us, want = timed(
        jax.jit(lambda a, b, c: ref.decode_attention_ref(a, b, c, 900)),
        q, kk, vv,
    )
    got = ops.decode_attention(q, kk, vv, jnp.int32(900), interpret=True)
    d = float(jnp.max(jnp.abs(got - want)))
    t.add("decode_attention", f"{B}x{H}x{S}x{hd}", fmt(t_us * 1e6), f"{d:.2e}")

    # ssd chunk scan
    Bt, Ss, nh, hp, ns = 2, 256, 4, 32, 16
    x2 = jax.random.normal(ks[0], (Bt, Ss, nh, hp)) * 0.5
    Bi = jax.random.normal(ks[1], (Bt, Ss, ns)) * 0.5
    Ci = jax.random.normal(ks[2], (Bt, Ss, ns)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, Ss, nh)))
    A = -jnp.exp(jax.random.normal(ks[0], (nh,)) * 0.3)
    from repro.models.ssm import ssd_scan as ssd_jnp

    t_us, (y_ref, h_ref) = timed(
        jax.jit(lambda *a: ssd_jnp(*a, 64)), x2, Bi, Ci, dt, A
    )
    y, h = ops.ssd_scan(x2, Bi, Ci, dt, A, 64, interpret=True)
    d = float(jnp.max(jnp.abs(y - y_ref)))
    t.add("ssd_scan", f"{Bt}x{Ss}x{nh}x{hp}", fmt(t_us * 1e6), f"{d:.2e}")

    # flash attention
    q3 = jax.random.normal(ks[0], (2, 512, 4, 64))
    k3 = jax.random.normal(ks[1], (2, 512, 2, 64))
    v3 = jax.random.normal(ks[2], (2, 512, 2, 64))
    t_us, want = timed(
        jax.jit(lambda a, b, c: ref.flash_attention_ref(
            a, jnp.repeat(b, 2, 2), jnp.repeat(c, 2, 2))),
        q3, k3, v3,
    )
    got = ops.flash_attention(q3, k3, v3, interpret=True)
    d = float(jnp.max(jnp.abs(got - want)))
    t.add("flash_attention", "2x512x4x64", fmt(t_us * 1e6), f"{d:.2e}")
    return t


def grouped_vs_loop() -> Table:
    """The engine's expert-stage choice: one grouped launch for all experts
    (ops.grouped_expert_ffn) vs a sequential per-expert loop over the same
    (E, C, D) buffer — the launch-count pathology MoE-Gen batches away."""
    t = Table("grouped_vs_loop",
              ["path", "shape", "wall_us", "speedup", "maxdiff"])
    E, C, D, F = 8, 512, 256, 512
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, D)) * 0.3).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[1], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[2], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[3], (E, F, D)) * 0.05).astype(jnp.bfloat16)
    shape = f"{E}x{C}x{D}x{F}"

    @jax.jit
    def one_expert(xe, g, u, d_):
        return (jax.nn.silu(xe @ g) * (xe @ u)) @ d_

    def loop_path():
        return jnp.stack(
            [one_expert(x[e], wg[e], wu[e], wd[e]) for e in range(E)]
        )

    t_loop, want = timed(loop_path)
    t_grp, got = timed(
        lambda: ops.grouped_expert_ffn(x, wg, wu, wd, use_kernel=False)
    )
    d = float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                              want.astype(jnp.float32))))
    t.add("per-expert-loop", shape, fmt(t_loop * 1e6), "1.0", "0")
    t.add("grouped(1 launch)", shape, fmt(t_grp * 1e6),
          fmt(t_loop / max(t_grp, 1e-12)), f"{d:.2e}")
    return t


ALL = [kernel_allclose, grouped_vs_loop]
