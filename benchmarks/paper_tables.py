"""Benchmarks reproducing each paper table/figure (cost-model driven).

Each function mirrors one table of the MoE-Gen paper with the models in our
assigned pool (mixtral-8x7b is the paper's own model; olmoe stands in for
the high-sparsity DeepSeek regime: top-8-of-64 routing).  The numbers come
from the same DAG critical-path estimator the planner optimizes — i.e. they
are the scheduler's predictions under the paper's published hardware
constants, which EXPERIMENTS.md compares against the paper's measurements.
"""
from __future__ import annotations

from benchmarks.common import Table, fmt
from repro.configs import get_config
from repro.core import baselines, planner
from repro.core import workload as W
from repro.core.dag_builder import Plan, estimate_decode
from repro.core.hardware import A5000_C1, A5000_C2, A6000_C3
from repro.data.datasets import DATASETS

SYSTEMS = ("vllm", "deepspeed", "flexgen", "moe-lightning")


# ---------------------------------------------------------------------------
# Table 1: expert batch / utilization / throughput
# ---------------------------------------------------------------------------
def table1_expert_util() -> Table:
    t = Table("table1_expert_util",
              ["model", "system", "phase", "expert_bsz", "util%", "tp"])
    hw = A5000_C2
    for arch in ("olmoe-1b-7b", "mixtral-8x7b"):
        cfg = get_config(arch)
        for phase in ("prefill", "decode"):
            # baseline: model-based batching (DeepSpeed-style)
            Bb = baselines.model_based_batch_limit(cfg, hw, 768)
            tokens = Bb * (512 if phase == "prefill" else 1)
            e_bsz_base = tokens * cfg.experts_per_token / cfg.num_experts
            est_b = (
                baselines.estimate_baseline_prefill(cfg, hw, 512, "deepspeed")
                if phase == "prefill"
                else baselines.estimate_baseline_decode(cfg, hw, 768, "deepspeed")
            )
            t.add(arch, "deepspeed", phase, int(e_bsz_base),
                  fmt(100 * hw.matmul_utilization(int(max(e_bsz_base, 1)))),
                  fmt(est_b.throughput))
            # MoE-Gen
            res = (
                planner.search_prefill(cfg, hw, 512)
                if phase == "prefill"
                else planner.search_decode(cfg, hw, 768)
            )
            tokens = res.plan.B * (512 if phase == "prefill" else 1)
            e_bsz = tokens * cfg.experts_per_token / cfg.num_experts
            t.add(arch, "moe-gen", phase, int(e_bsz),
                  fmt(100 * hw.matmul_utilization(int(max(e_bsz, 1)))),
                  fmt(res.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Figure 3: saturation curves
# ---------------------------------------------------------------------------
def fig3_saturation() -> Table:
    t = Table("fig3_saturation",
              ["tokens", "achieved_util%", "idle_frac%"])
    hw = A5000_C2
    cfg = get_config("mixtral-8x7b")
    e_bytes = W.expert_weight_bytes(cfg)
    for p in range(0, 15):
        b = 2 ** p
        util = hw.matmul_utilization(b)
        compute = b * W.expert_flops_per_token(cfg) / (hw.device_flops * util)
        fetch = e_bytes / hw.htod_bw
        idle = max(0.0, 1.0 - compute / fetch)
        t.add(b, fmt(100 * util), fmt(100 * idle))
    return t


# ---------------------------------------------------------------------------
# Figure 4: fetch traffic vs dataset size (full vs partial KV offload)
# ---------------------------------------------------------------------------
def fig4_kv_offload() -> Table:
    t = Table("fig4_kv_offload",
              ["n_seqs", "traffic_full_offload_GB", "traffic_kv_on_gpu_GB"])
    hw = A5000_C1
    cfg = get_config("mixtral-8x7b")
    ctx = 768
    res = planner.search_decode(cfg, hw, ctx)
    B_full = res.plan.B
    B_gpu = baselines.model_based_batch_limit(cfg, hw, ctx)
    est_full = res.estimate
    est_gpu = estimate_decode(
        cfg, hw,
        Plan(B=B_gpu, b_a=B_gpu, b_e=1 << 30, kv_on_gpu=True), ctx,
    )
    for n in (512, 2048, 8192, 32768):
        steps_full = -(-n // B_full)
        steps_gpu = -(-n // B_gpu)
        t.add(n, fmt(steps_full * est_full.htod_bytes / 1e9),
              fmt(steps_gpu * est_gpu.htod_bytes / 1e9))
    return t


# ---------------------------------------------------------------------------
# Table 4: time to complete datasets
# ---------------------------------------------------------------------------
def table4_dataset_time() -> Table:
    t = Table("table4_dataset_time", ["dataset", "system", "hours"])
    hw = A5000_C2
    cfg = get_config("mixtral-8x7b")
    for ds in ("mmlu", "gsm8k", "chatbot-arena"):
        spec = DATASETS[ds]
        for system in SYSTEMS:
            pre = baselines.estimate_baseline_prefill(
                cfg, hw, spec.prompt_len, system
            )
            dec = baselines.estimate_baseline_decode(
                cfg, hw, spec.prompt_len + spec.decode_len // 2, system,
                decode_len=spec.decode_len,
            )
            total = (
                spec.num_sequences * spec.prompt_len / pre.throughput
                + spec.num_sequences * spec.decode_len / dec.throughput
            )
            t.add(ds, system, fmt(total / 3600))
        for name, cpu in (("moe-gen(G)", False), ("moe-gen(H)", True)):
            pre = planner.search_prefill(cfg, hw, spec.prompt_len)
            dec = planner.search_decode(
                cfg, hw, spec.prompt_len + spec.decode_len // 2,
                use_cpu_attention=cpu,
            )
            total = (
                spec.num_sequences * spec.prompt_len
                / pre.estimate.throughput
                + spec.num_sequences * spec.decode_len
                / dec.estimate.throughput
            )
            t.add(ds, name, fmt(total / 3600))
    return t


# ---------------------------------------------------------------------------
# Table 6: decoding throughput
# ---------------------------------------------------------------------------
def table6_decode_throughput() -> Table:
    t = Table("table6_decode",
              ["model", "decode_len", "system", "tokens_per_s"])
    hw = A5000_C2
    for arch in ("mixtral-8x7b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b"):
        cfg = get_config(arch)
        for dlen in (256, 1024):
            ctx = 512 + dlen // 2
            for system in SYSTEMS:
                est = baselines.estimate_baseline_decode(
                    cfg, hw, ctx, system, decode_len=dlen
                )
                t.add(arch, dlen, system, fmt(est.throughput))
            g = planner.search_decode(cfg, hw, ctx, use_cpu_attention=False)
            h = planner.search_decode(cfg, hw, ctx, use_cpu_attention=True)
            t.add(arch, dlen, "moe-gen(G)", fmt(g.estimate.throughput))
            t.add(arch, dlen, "moe-gen(H)", fmt(h.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Table 7: prefill throughput
# ---------------------------------------------------------------------------
def table7_prefill_throughput() -> Table:
    t = Table("table7_prefill", ["model", "system", "tokens_per_s"])
    hw = A5000_C2
    for arch in ("mixtral-8x7b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b"):
        cfg = get_config(arch)
        for system in SYSTEMS:
            est = baselines.estimate_baseline_prefill(cfg, hw, 512, system)
            t.add(arch, system, fmt(est.throughput))
        res = planner.search_prefill(cfg, hw, 512)
        t.add(arch, "moe-gen", fmt(res.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Table 8: long-context generation
# ---------------------------------------------------------------------------
def table8_long_context() -> Table:
    t = Table("table8_long_context",
              ["workload", "system", "prefill_tp", "decode_tp"])
    hw = A5000_C1
    cfg = get_config("mixtral-8x7b")
    for ds in ("longbench-16k-8k", "longbench-8k-16k", "longbench-8k-4k",
               "longbench-4k-2k"):
        spec = DATASETS[ds]
        ctx = spec.prompt_len + spec.decode_len // 2
        for system in ("vllm", "deepspeed", "flexgen", "moe-lightning"):
            pre = baselines.estimate_baseline_prefill(
                cfg, hw, spec.prompt_len, system
            )
            dec = baselines.estimate_baseline_decode(
                cfg, hw, ctx, system, decode_len=spec.decode_len
            )
            t.add(ds, system, fmt(pre.throughput), fmt(dec.throughput))
        pre = planner.search_prefill(cfg, hw, spec.prompt_len)
        dec = planner.search_decode(cfg, hw, ctx)
        t.add(ds, "moe-gen(H)", fmt(pre.estimate.throughput),
              fmt(dec.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Table 9: insufficient batch sizes
# ---------------------------------------------------------------------------
def table9_small_batch() -> Table:
    t = Table("table9_small_batch", ["model", "B", "system", "tp"])
    hw = A5000_C1
    for arch in ("mixtral-8x7b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        for B in (1, 32):
            for system in ("deepspeed", "flexgen"):
                est = baselines.estimate_baseline_decode(
                    cfg, hw, 512, system
                )
                # baseline at its native batch, rescaled to B
                scale = min(1.0, B / max(est.tokens, 1))
                t.add(arch, B, system, fmt(est.throughput * scale))
            res = planner.search_decode(cfg, hw, 512, B=B)
            t.add(arch, B, "moe-gen(G)", fmt(res.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Figure 7: omega sweep
# ---------------------------------------------------------------------------
def fig7_omega_sweep() -> Table:
    t = Table("fig7_omega_sweep", ["omega", "decode_tp"])
    hw = A5000_C1
    cfg = get_config("mixtral-8x7b")
    for i in range(11):
        w = i / 10
        res = planner.search_decode(cfg, hw, 256 + 16, omega_grid=[w])
        t.add(w, fmt(res.estimate.throughput))
    return t


# ---------------------------------------------------------------------------
# Table 10: omega vs CPU power
# ---------------------------------------------------------------------------
def table10_omega_vs_cpu() -> Table:
    t = Table("table10_omega_vs_cpu", ["model", "testbed", "omega"])
    for arch in ("mixtral-8x7b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        for hw in (A5000_C1, A5000_C2, A6000_C3):
            if W.model_bytes(cfg) > hw.host_mem_bytes:
                t.add(arch, hw.name, "N/A")
                continue
            res = planner.search_decode(cfg, hw, 768)
            t.add(arch, hw.name, res.plan.omega)
    return t


ALL = [
    table1_expert_util,
    fig3_saturation,
    fig4_kv_offload,
    table4_dataset_time,
    table6_decode_throughput,
    table7_prefill_throughput,
    table8_long_context,
    table9_small_batch,
    fig7_omega_sweep,
    table10_omega_vs_cpu,
]
