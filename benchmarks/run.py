"""Benchmark harness: one table per paper table/figure.

Prints human tables plus ``name,...`` CSV lines.  Cost-model tables use the
paper's A5000 hardware constants; engine/kernel tables measure real
execution on this machine.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import engine_walltime, kernels, paper_tables

    suites = list(paper_tables.ALL) + list(engine_walltime.ALL) + list(kernels.ALL)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    csv = []
    for fn in suites:
        if only and only not in fn.__name__:
            continue
        table = fn()
        table.show()
        csv.extend(table.csv_lines())
    print("\n--- CSV ---")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
