"""Benchmark harness: one table per paper table/figure.

Prints human tables plus ``name,...`` CSV lines.  Cost-model tables use the
paper's A5000 hardware constants; engine/kernel tables measure real
execution on this machine.

``--json PATH`` additionally writes the selected tables as machine-readable
JSON (``[{"name", "columns", "rows"}, ...]``) — the perf-trajectory format
the slow CI job uploads as ``BENCH_<name>.json`` artifacts.
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    from benchmarks import (engine_walltime, expert_parallel,
                            expert_prefetch, kernels, kv_paging,
                            paper_tables)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on suite function names")
    ap.add_argument("--json", dest="json_path", default=None, metavar="PATH",
                    help="write the selected tables as JSON to PATH")
    args = ap.parse_args()

    suites = (list(paper_tables.ALL) + list(engine_walltime.ALL)
              + list(kernels.ALL) + list(kv_paging.ALL)
              + list(expert_prefetch.ALL) + list(expert_parallel.ALL))
    csv = []
    tables = []
    for fn in suites:
        if args.only and args.only not in fn.__name__:
            continue
        table = fn()
        table.show()
        tables.append(table)
        csv.extend(table.csv_lines())
    print("\n--- CSV ---")
    for line in csv:
        print(line)
    if args.json_path:
        payload = [
            {"name": t.name, "columns": t.columns, "rows": t.rows}
            for t in tables
        ]
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {args.json_path} ({len(payload)} tables)")


if __name__ == "__main__":
    main()
