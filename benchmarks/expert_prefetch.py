"""Predictive per-expert prefetch vs whole-stack expert streaming.

The same skewed-routing workload (router weights biased so two experts
take most of the traffic — the regime the MoE-Gen capacity planner calls
imbalanced) served four ways: fully resident (the token reference),
whole-stack streaming (every MoE layer moves ALL E experts' bytes per
step — the legacy stream path), predictive per-expert streaming (layer
*l*'s gate tap predicts layer *l+1*'s expert set; only predicted + used
experts move), and predictive streaming with the hot-expert device LRU
(measured-hot experts stay resident, so skew converts directly into
avoided htod traffic).  Tokens are identical across all rows — prediction
moves WHEN bytes move, never WHICH math runs.

CPU caveat: no real PCIe channel here, so ``htod_gb`` — the bytes the
predictor avoided moving — is the paper-relevant column; wall-clock
decode tok/s mostly reflects per-expert fetch overhead at smoke scale.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Table, fmt
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.models import model as M
from repro.serving.scheduler import Request, serve_dataset
from repro.serving.weights import ParamStore


def _skewed_params(cfg, key, hot=(0, 1), bias=6.0):
    """Init params, then bias every MoE router toward the ``hot`` experts
    so the measured routing histogram is far from balanced."""
    params = M.init_params(cfg, key)
    for slot in params["layers"]:
        if "moe" in slot:
            r = np.asarray(slot["moe"]["router"]).copy()
            r[..., list(hot)] += bias * float(np.abs(r).mean() + 1e-6)
            slot["moe"]["router"] = jax.numpy.asarray(r)
    return params


def expert_prefetch() -> Table:
    t = Table("expert_prefetch",
              ["mode", "decode_tok_per_s", "htod_gb", "pred_hit%",
               "lru_hit%", "drop%", "skew_x", "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = _skewed_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    DEC = 24
    prompts = [rng.integers(5, cfg.vocab_size - 5, 24).tolist()
               for _ in range(8)]
    reqs = lambda: [Request(prompt=p, decode_len=DEC) for p in prompts]
    plan = Plan(B=4, b_a=4, b_e=8, omega=0.0)
    # a predicted set SMALLER than E is what makes prediction meaningful:
    # k-hat = E degenerates to whole-stack prefetch (all experts staged)
    khat = max(2, min(cfg.num_experts - 2, 2 * cfg.experts_per_token))
    modes = [
        ("resident", None),
        ("whole-stack", dict(predict_topk=0)),
        ("predictive", dict(predict_topk=khat, lru_bytes=0.0)),
        ("predictive+lru", dict(predict_topk=khat, lru_bytes=1e9)),
    ]

    def run(store_kw):
        store = (None if store_kw is None else ParamStore(
            cfg, params, resident_bytes=0.0, **store_kw
        ))
        return serve_dataset(cfg, params, reqs(), plan, DEC, max_seq=64,
                             store=store)

    for _, kw in modes:             # untimed warm-up (per-mode jit caches)
        run(kw)
    ref = None
    for mode, kw in modes:
        rep = run(kw)
        toks = np.concatenate([np.asarray(r.tokens).reshape(-1)
                               for r in rep.request_results])
        if ref is None:
            ref = toks
        match = float((ref == toks).mean())
        routed = (0 if rep.expert_load is None
                  else int(rep.expert_load.sum()))
        drop = rep.expert_tokens_dropped / routed if routed else 0.0
        t.add(mode, fmt(rep.decode_throughput), fmt(rep.htod_gb, 4),
              fmt(100 * rep.pred_hit_rate), fmt(100 * rep.lru_hit_rate),
              fmt(100 * drop, 2), fmt(rep.routing_skew, 2),
              fmt(100 * match))
    return t


ALL = [expert_prefetch]
