"""Measured wall-time of the REAL engine on this CPU (not the cost model).

Module-based batching vs the model-based reference loop on a smoke-scale
Mixtral.  On a CPU there is no PCIe/HBM hierarchy, so the paper's speedups
do not manifest here — this benchmark demonstrates the engine is a real,
runnable system and quantifies its Python/dispatch overhead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Table, fmt
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.models import model as M
from repro.serving.generate import greedy_generate


def engine_walltime() -> Table:
    t = Table("engine_walltime",
              ["system", "prefill_s", "decode_tok_per_s", "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, DEC = 8, 32, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    # reference (model-based batching)
    t0 = time.perf_counter()
    ref = greedy_generate(cfg, params, toks, DEC)
    jax.block_until_ready(ref)
    t_ref = time.perf_counter() - t0

    # module-based engine: grouped dispatch vs the per-expert loop oracle
    t.add("model-based(ref)", fmt(t_ref, 2), fmt(B * DEC / t_ref), "100")
    for path in ("grouped", "loop"):
        eng = ModuleBatchingEngine(
            cfg, params, Plan(B=B, b_a=4, b_e=64, omega=0.0),
            max_seq=S + DEC, expert_path=path,
        )
        t0 = time.perf_counter()
        lg = eng.prefill(toks)
        jax.block_until_ready(lg)
        t_pre = time.perf_counter() - t0
        out = [jnp.argmax(lg, -1)]
        t0 = time.perf_counter()
        for i in range(DEC - 1):
            lg = eng.decode_step(out[-1], S + i)
            out.append(jnp.argmax(lg, -1))
        jax.block_until_ready(out[-1])
        t_dec = time.perf_counter() - t0
        got = jnp.stack(out, 1)

        match = float(jnp.mean((ref == got).astype(jnp.float32)))
        t.add(f"moe-gen-engine({path})", fmt(t_pre, 2),
              fmt(B * (DEC - 1) / max(t_dec, 1e-9)), fmt(100 * match))
    return t


def scheduler_modes() -> Table:
    """Static vs continuous scheduling on a mixed-decode_len workload.

    The workload the continuous scheduler exists for: decode lengths drawn
    from {8, 32, 128} (short chats to long generations).  The static
    scheduler decodes every batch to its longest member; the continuous
    scheduler recycles finished slots, so it executes strictly fewer
    decode-step*slot units for the same tokens (occupancy -> 1).
    """
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    t = Table("scheduler_modes",
              ["scheduler", "total_s", "decode_tok_per_s", "slot_steps",
               "occupancy%", "mean_latency_s"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = synthetic_requests(
        DatasetSpec("mixed", 8, 24, 32), cfg.vocab_size,
        prompt_lens=[24, 12, 17], decode_lens=[8, 32, 128],
    )
    plan = Plan(B=4, b_a=4, b_e=64, omega=0.0)
    for mode in ("static", "continuous"):
        rep = serve_dataset(cfg, params, reqs, plan, 32, scheduler=mode)
        t.add(mode, fmt(rep.total_s, 2), fmt(rep.decode_throughput),
              str(rep.decode_slot_steps), fmt(100 * rep.occupancy),
              fmt(rep.mean_latency_s, 2))
    return t


def online_arrivals() -> Table:
    """Closed-loop drain vs open-loop Poisson arrivals (online serving).

    The same mixed workload served by the continuous scheduler under the
    offline protocol (every request due at t=0) and as an open-loop online
    stream at a few Poisson rates.  At high rates the run converges to the
    drain's throughput (arrivals never gate the batch); at low rates the
    batch drains between arrivals, queue wait vanishes and TTFT approaches
    pure prefill latency — throughput is paid for it.  TTFT percentiles
    are measured arrival -> first token on the server's virtual clock.

    CPU-smoke caveat: staggered arrivals admit waves of sizes the drain
    run never sees, and each fresh (wave, pad) shape pays a one-time XLA
    compile that lands in that request's TTFT — on real hardware with a
    warmed serving process the rate sweep, not the compiles, dominates.
    """
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving import arrivals
    from repro.serving.scheduler import serve_dataset

    t = Table("online_arrivals",
              ["mode", "total_s", "decode_tok_per_s", "p50_ttft_s",
               "p95_ttft_s", "mean_tpot_ms", "mean_queue_wait_s"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n = 8
    make = lambda times: synthetic_requests(
        DatasetSpec("online", n, 16, 16), cfg.vocab_size,
        prompt_lens=[16, 9, 12], decode_lens=[6, 16, 24],
        arrivals=times,
    )
    plan = Plan(B=4, b_a=4, b_e=64, omega=0.0)
    # untimed warm-up: the runs share module-level jit caches, so without
    # it the FIRST mode pays all XLA compilation and its TTFT is compile
    # time, not serving latency
    serve_dataset(cfg, params, make(None), plan, 16, scheduler="continuous")
    runs = [("drain(closed-loop)", None)] + [
        (f"poisson@{rate}rps", arrivals.poisson(n, rate, seed=0))
        for rate in (8.0, 2.0, 0.5)
    ]
    for mode, times in runs:
        rep = serve_dataset(cfg, params, make(times), plan, 16,
                            scheduler="continuous")
        t.add(mode, fmt(rep.total_s, 2), fmt(rep.decode_throughput),
              fmt(rep.ttft_percentile(50), 3), fmt(rep.ttft_percentile(95), 3),
              fmt(rep.mean_tpot_s * 1e3, 1), fmt(rep.mean_queue_wait_s, 3))
    return t


def weight_streaming() -> Table:
    """Resident vs streamed weight execution (the paper's S_Params policy).

    Three residency modes over the same engine and plan:

    * ``resident``            — every weight pinned on device (baseline);
    * ``streamed-serial``     — weights fetched on demand, copy serialized
                                with compute (the DeepSpeed-style baseline);
    * ``streamed-overlapped`` — double-buffered async prefetch: layer l+1's
                                htod copy issued before layer l's grouped
                                GEMM (the paper's Fig. 6 overlap).

    On one CPU there is no real PCIe channel, so the overlap gain is
    bounded by dispatch overhead — the benchmark demonstrates the streamed
    store is real (htod bytes > 0, tokens identical to resident) and that
    prefetch does not cost throughput.
    """
    t = Table("weight_streaming",
              ["mode", "prefill_s", "decode_tok_per_s", "htod_gb",
               "stall_s", "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, DEC = 8, 32, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    plan = Plan(B=B, b_a=4, b_e=64, omega=0.0)
    ref = None
    for mode in ("resident", "streamed-serial", "streamed-overlapped"):
        eng = ModuleBatchingEngine(
            cfg, params, plan, max_seq=S + DEC,
            stream_weights=mode != "resident",
            resident_bytes=0.0,
            prefetch=mode == "streamed-overlapped",
        )
        # untimed warm-up: the engines share module-level jit caches, so
        # without it the FIRST mode pays all XLA compilation and the table
        # shows streaming "beating" residency
        wl = eng.prefill(toks)
        jax.block_until_ready(eng.decode_step(jnp.argmax(wl, -1), S))
        eng.sync_stats()
        eng.stats = type(eng.stats)()      # reset accounting post warm-up
        t0 = time.perf_counter()
        lg = eng.prefill(toks)
        jax.block_until_ready(lg)
        t_pre = time.perf_counter() - t0
        out = [jnp.argmax(lg, -1)]
        t0 = time.perf_counter()
        for i in range(DEC - 1):
            lg = eng.decode_step(out[-1], S + i)
            out.append(jnp.argmax(lg, -1))
        jax.block_until_ready(out[-1])
        t_dec = time.perf_counter() - t0
        got = jnp.stack(out, 1)
        if ref is None:
            ref = got
        stats = eng.sync_stats()
        assert (mode == "resident") == (stats.weight_htod_bytes == 0), mode
        match = float(jnp.mean((ref == got).astype(jnp.float32)))
        t.add(mode, fmt(t_pre, 2),
              fmt(B * (DEC - 1) / max(t_dec, 1e-9)),
              fmt(stats.weight_htod_bytes / 1e9, 3),
              fmt(stats.prefetch_wait_s, 3), fmt(100 * match))
    return t


def decode_dispatch() -> Table:
    """Per-module vs fused decode launches (the few-large-launches thesis
    applied to the decode hot path).

    Decode walltime over the same engine state under three execution
    models: the per-module dispatch loop (one jitted launch per module per
    tick), the fused macro-step (ONE donated launch per tick), and fused
    multi-token chunks (ONE launch per T ticks, T in {4, 16, 64}).  On a
    CPU the decode hot path is dominated by exactly the Python/XLA
    dispatch overhead the fused path removes, so the chunked rows should
    clearly beat per-module decode; tokens are bit-identical across all
    rows (the fused/per-module contract).
    """
    from repro.serving.sampling import BatchSampler

    t = Table("decode_dispatch",
              ["mode", "decode_tok_per_s", "dispatches_per_tok",
               "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, DEC = 8, 32, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    plan = Plan(B=B, b_a=8, b_e=64, omega=0.0)
    ref = None
    modes = [("per-module", False, 1), ("fused-step", True, 1)] + [
        (f"fused-chunk-{T}", True, T) for T in (4, 16, 64)
    ]
    for mode, fused, chunk in modes:
        eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                                   fused_decode=fused)
        cur = jnp.argmax(eng.prefill(toks), -1)
        sampler = BatchSampler.uniform(B, None)

        def run_decode():
            out = []
            for lo in range(0, DEC, chunk):
                mat = eng.decode_chunk(cur if not out else out[-1][:, -1],
                                       jnp.int32(S + lo), sampler,
                                       min(chunk, DEC - lo))
                out.append(mat)
            jax.block_until_ready(out[-1])
            return jnp.concatenate(out, axis=1)

        run_decode()                       # untimed warm-up (XLA compiles);
        #                                    greedy decode from the same
        #                                    state is idempotent, so the
        #                                    timed rerun is exact
        from repro.core.engine import dispatch_count

        d0 = dispatch_count()
        t0 = time.perf_counter()
        got = run_decode()
        dt = time.perf_counter() - t0
        disp = dispatch_count() - d0
        if ref is None:
            ref = got
        match = float(jnp.mean((ref == got).astype(jnp.float32)))
        t.add(mode, fmt(B * DEC / max(dt, 1e-9)),
              fmt(disp / (B * DEC), 3), fmt(100 * match))
    return t


ALL = [engine_walltime, scheduler_modes, online_arrivals, weight_streaming,
       decode_dispatch]
