"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, List, Sequence


class Table:
    def __init__(self, name: str, columns: Sequence[str]):
        self.name = name
        self.columns = list(columns)
        self.rows: List[list] = []

    def add(self, *row):
        assert len(row) == len(self.columns), (row, self.columns)
        self.rows.append(list(row))

    def show(self) -> None:
        widths = [
            max(len(str(c)), *(len(str(r[i])) for r in self.rows), 4)
            for i, c in enumerate(self.columns)
        ] if self.rows else [len(str(c)) for c in self.columns]
        print(f"\n== {self.name} ==")
        print("  ".join(str(c).ljust(w) for c, w in zip(self.columns, widths)))
        for r in self.rows:
            print("  ".join(str(v).ljust(w) for v, w in zip(r, widths)))

    def csv_lines(self) -> List[str]:
        out = []
        for r in self.rows:
            out.append(f"{self.name}," + ",".join(str(v) for v in r))
        return out


def timed(fn: Callable, *args, repeats: int = 3, warmup: int = 1):
    """Median wall-time of fn(*args) in seconds (after block_until_ready)."""
    import jax

    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], r


def fmt(x, nd=1):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x >= 100:
            return f"{x:.0f}"
        return f"{x:.{nd}f}"
    return str(x)
