"""Paged tiered KV cache: contiguous vs paged vs prefix-hit serving.

The same shared-prefix workload served four ways: the contiguous baseline,
the paged cache fully device-resident (Mode A — bookkeeping only, fused
decode intact), the paged cache with every frame host-tier streamed through
the prefetch window (Mode B — the per-layer loop), and Mode A with the
prefix cache on (shared spans admitted by page-row copy instead of
prefill).  Tokens are identical across all rows (the paged-cache exactness
contract); the table quantifies what each tier costs on this machine and
what prefix hits save.

CPU caveat: there is no real PCIe channel here, so the Mode B stream cost
is host<->device copy overhead rather than true transfer time — the row
demonstrates the host tier is real (page htod GB > 0) and exact, not its
GPU economics.  Likewise prefix-hit admission issues one small launch set
per hit, so at smoke scale its wall-clock prefill_s can exceed the cold
run even though it computes far fewer token-positions; ``prefill_tok``
(token-positions actually prefilled) is the scale-independent measure of
the work the prefix cache skips.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Table, fmt
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.models import model as M
from repro.serving.scheduler import Request, serve_dataset


def kv_paging() -> Table:
    t = Table("kv_paging",
              ["mode", "total_s", "prefill_s", "prefill_tok",
               "decode_tok_per_s", "page_htod_gb", "prefix_hit_rate%",
               "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # prefix-heavy workload (system prompt + short questions): 12 requests
    # over 3 waves of B=4 — waves 2-3 hit the stored prefix, so the cold
    # run prefills 3 waves of ~53-token prompts while the warm run
    # prefills one, plus per-hit suffixes of <= 7 tokens
    rng = np.random.default_rng(0)
    shared = [int(x) for x in rng.integers(5, cfg.vocab_size - 5, size=48)]
    tails = [rng.integers(5, cfg.vocab_size - 5, n).tolist()
             for n in (5, 3, 7, 4, 6, 2, 5, 3, 7, 4, 6, 2)]
    DEC = 16

    def make():
        return [Request(prompt=shared + [int(x) for x in tl], decode_len=DEC)
                for tl in tails]

    plan = Plan(B=4, b_a=4, b_e=64, omega=0.0)
    modes = [
        ("contiguous", {}),
        ("paged-resident", dict(kv_page_tokens=16)),
        ("paged-streamed", dict(kv_page_tokens=16, device_kv_gb=1e-9)),
        ("prefix-hit", dict(kv_page_tokens=16, prefix_cache=True)),
    ]
    # untimed warm-up per mode: the runs share module-level jit caches but
    # each mode compiles its own attention path (fused, paged, suffix)
    for _, kw in modes:
        serve_dataset(cfg, params, make(), plan, DEC, max_seq=96, **kw)
    ref = None
    for mode, kw in modes:
        rep = serve_dataset(cfg, params, make(), plan, DEC, max_seq=96, **kw)
        toks = np.concatenate([np.asarray(r.tokens).reshape(-1)
                               for r in rep.request_results])
        if ref is None:
            ref = toks
        match = float((ref == toks).mean())
        t.add(mode, fmt(rep.total_s, 2), fmt(rep.prefill_s, 3),
              rep.prefill_tokens, fmt(rep.decode_throughput),
              fmt(rep.kv_htod_gb, 4), fmt(100 * rep.prefix_hit_rate),
              fmt(100 * match))
    return t


ALL = [kv_paging]
