"""Expert-parallel decode: single-device vs serial a2a vs pipelined a2a.

The same workload served three ways: the single-device grouped path (the
token reference), expert-parallel dispatch with ONE all-to-all per decode
step (``ep_chunks=1`` — the exchange is fully exposed), and the pipelined
schedule (``ep_chunks=4`` — chunk k+1's exchange overlaps chunk k's expert
GEMMs, the EPS-MoE shape).  Tokens are identical across all rows — the
mesh moves WHERE experts run, never WHICH tokens come out — so
``tokens_match%`` doubles as the bit-identity check and ``a2a_gb`` is the
exchanged collective payload from the ServeReport.

CPU caveat: 8 virtual XLA devices share one physical socket, so wall-clock
tok/s mostly measures dispatch overhead at smoke scale, not real overlap;
``a2a_gb`` and the pipelined-vs-serial ORDER are the paper-relevant
signals.  Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
with fewer than 2 visible devices the mesh rows degrade to ep=1
(single-device execution, noted in the ``ep`` column).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Table, fmt
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.models import model as M
from repro.serving.scheduler import Request, serve_dataset
from repro.sharding.specs import ShardCtx


def expert_parallel() -> Table:
    t = Table("expert_parallel",
              ["mode", "ep", "chunks", "decode_tok_per_s", "a2a_gb",
               "collectives", "tokens_match%"])
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    DEC = 24
    prompts = [rng.integers(5, cfg.vocab_size - 5, 24).tolist()
               for _ in range(8)]
    reqs = lambda: [Request(prompt=p, decode_len=DEC) for p in prompts]
    plan = Plan(B=8, b_a=8, b_e=64, omega=0.0, decode_chunk=4)

    ep = min(4, len(jax.devices()))
    if ep < 2:
        ep = 1                      # degraded: no mesh to shard over
    sctx = None
    if ep > 1:
        sctx = ShardCtx(mesh=jax.make_mesh((1, ep), ("data", "model")),
                        batch_axes=("data",), model_axis="model",
                        moe_dispatch="a2a")
    modes = [
        ("single-device", None, 1),
        ("ep-serial", sctx, 1),
        ("ep-pipelined", sctx, 4),
    ]

    def run(ctx, chunks):
        return serve_dataset(cfg, params, reqs(), plan, DEC, max_seq=64,
                             sctx=ctx, ep_chunks=chunks)

    for _, ctx, chunks in modes:    # untimed warm-up (per-mode jit caches)
        run(ctx, chunks)
    ref = None
    for mode, ctx, chunks in modes:
        rep = run(ctx, chunks)
        toks = np.concatenate([np.asarray(r.tokens).reshape(-1)
                               for r in rep.request_results])
        if ref is None:
            ref = toks
        match = float((ref == toks).mean())
        t.add(mode, 1 if ctx is None else ep, chunks,
              fmt(rep.decode_throughput), fmt(rep.a2a_gb, 4),
              rep.collective_dispatches, fmt(100 * match))
    return t


ALL = [expert_parallel]
