"""Request-lifecycle serving API: Server facade, sampling, online arrivals.

(The hypothesis property test for mixed greedy/sampled batches lives in
test_properties.py, the only module allowed to import hypothesis.)
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.data.datasets import DatasetSpec, synthetic_requests
from repro.models import model as M
from repro.serving import arrivals
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import serve_dataset
from repro.serving.server import Request, ServeConfig, Server, StreamConfig

KEY = jax.random.PRNGKey(0)


def _mixtral():
    cfg = get_config("mixtral-8x7b", smoke=True)
    return cfg, M.init_params(cfg, KEY)


def test_serving_package_exports_the_serving_api():
    import repro.serving as S

    for name in ("Server", "ServeConfig", "StreamConfig", "SamplingParams",
                 "Request", "RequestHandle", "ServeReport", "RequestResult",
                 "serve_dataset", "arrivals", "pad_requests",
                 "greedy_generate", "cache_from_prefill", "ParamStore"):
        assert hasattr(S, name), name
        assert name in S.__all__, name


def test_server_facade_submit_step_run_and_streaming():
    """The lifecycle surface: submit -> handles, step() drives the batch,
    per-token callbacks and handle.stream() see the same tokens the report
    records, statuses progress queued -> running -> finished."""
    cfg, params = _mixtral()
    reqs = synthetic_requests(DatasetSpec("t", 3, 8, 4), cfg.vocab_size,
                              prompt_lens=[8, 5, 7])
    server = Server(cfg, params, Plan(B=2, b_a=2, b_e=16, omega=0.0),
                    serve=ServeConfig(scheduler="continuous", decode_len=4))
    seen = []
    handles = [server.submit(r, on_token=lambda h, t: seen.append((h.index, t)))
               for r in reqs]
    assert [h.status for h in handles] == ["queued"] * 3
    assert [h.index for h in handles] == [0, 1, 2]
    # manual stepping works and terminates
    steps = 0
    while server.step():
        steps += 1
        assert steps < 100
    report = server.finalize()
    assert all(h.finished for h in handles)
    assert len(report.request_results) == 3
    for h, r in zip(handles, report.request_results):
        assert r.index == h.index
        assert np.array_equal(r.tokens, np.asarray(h.tokens))
        # callbacks fired exactly the recorded stream, in order
        assert [t for i, t in seen if i == h.index] == h.tokens
        assert r.ttft_s >= 0 and r.queue_wait_s >= 0 and r.tpot_s >= 0
    # an exhausted stream replays the recorded tokens without stepping
    assert list(handles[0].stream()) == handles[0].tokens


def test_server_handle_stream_drives_the_server():
    cfg, params = _mixtral()
    server = Server(cfg, params, Plan(B=1, b_a=1, b_e=16, omega=0.0),
                    serve=ServeConfig(decode_len=4))
    h = server.submit(Request(np.arange(6, dtype=np.int32), 4))
    toks = list(h.stream())          # pulls step() until the stream ends
    assert h.finished and len(toks) == 4
    assert toks == h.tokens


def test_server_matches_serve_dataset_wrapper():
    """The wrapper is a thin facade: a Server run with the same config
    serves identical tokens and the same report shape."""
    cfg, params = _mixtral()
    reqs = synthetic_requests(DatasetSpec("t", 5, 10, 4), cfg.vocab_size,
                              prompt_lens=[10, 6], decode_lens=[3, 5])
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    for sched in ("static", "continuous"):
        wrapped = serve_dataset(cfg, params, reqs, plan, 4, scheduler=sched)
        server = Server(cfg, params, plan,
                        serve=ServeConfig(scheduler=sched, decode_len=4))
        for r in reqs:
            server.submit(r)
        direct = server.run()
        assert len(direct.request_results) == len(wrapped.request_results)
        for a, b in zip(wrapped.request_results, direct.request_results):
            assert a.index == b.index
            assert np.array_equal(a.tokens, b.tokens), (sched, a.index)
        assert len(direct.results) == len(wrapped.results)


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------
def test_sampling_deterministic_across_runs_and_schedulers():
    """Same seed + same SamplingParams => identical tokens across runs and
    across the static/continuous schedulers (the per-request key is folded
    with the token index, not slot or global step)."""
    cfg, params = _mixtral()
    sp = SamplingParams(temperature=0.9, top_k=5, seed=7)
    reqs = synthetic_requests(DatasetSpec("s", 5, 9, 5), cfg.vocab_size,
                              prompt_lens=[9, 6, 7], decode_lens=[3, 5],
                              sampling=sp)
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    runs = [serve_dataset(cfg, params, reqs, plan, 5, scheduler=s)
            for s in ("static", "static", "continuous")]
    for rep in runs[1:]:
        for a, b in zip(runs[0].request_results, rep.request_results):
            assert a.index == b.index
            assert np.array_equal(a.tokens, b.tokens), a.index
    # sampled decode really deviates from greedy somewhere
    greedy_rep = serve_dataset(cfg, params, [
        Request(r.prompt, r.decode_len) for r in reqs
    ], plan, 5)
    assert any(
        not np.array_equal(a.tokens, b.tokens)
        for a, b in zip(runs[0].request_results, greedy_rep.request_results)
    )


def test_temperature_zero_is_greedy():
    cfg, params = _mixtral()
    reqs = synthetic_requests(DatasetSpec("g", 3, 8, 4), cfg.vocab_size)
    plan = Plan(B=3, b_a=2, b_e=16, omega=0.0)
    base = serve_dataset(cfg, params, reqs, plan, 4)
    t0 = serve_dataset(cfg, params, [
        Request(r.prompt, r.decode_len,
                sampling=SamplingParams(temperature=0.0, seed=3))
        for r in reqs
    ], plan, 4)
    for a, b in zip(base.request_results, t0.request_results):
        assert np.array_equal(a.tokens, b.tokens), a.index


def test_engine_generate_sampled_is_reproducible():
    """engine.generate(sampling=...) is bit-reproducible and rows sharing
    one seed draw distinct streams (row index folded into the key)."""
    from repro.core.engine import ModuleBatchingEngine

    import jax.numpy as jnp

    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    row = jax.random.randint(KEY, (1, 10), 0, cfg.vocab_size)
    toks = jnp.tile(row, (3, 1))     # identical rows: only the per-row salt
    sp = SamplingParams(temperature=1.0, seed=11)  # can decorrelate them
    outs = []
    for _ in range(2):
        eng = ModuleBatchingEngine(cfg, params,
                                   Plan(B=3, b_a=2, b_e=8, omega=0.0),
                                   max_seq=16)
        outs.append(np.asarray(eng.generate(toks, 5, sampling=sp)))
    assert np.array_equal(outs[0], outs[1])
    assert not np.array_equal(outs[0][0], outs[0][1])   # decorrelated rows


# ---------------------------------------------------------------------------
# Online arrivals
# ---------------------------------------------------------------------------
def test_arrival_zero_matches_drain():
    """With every arrival_s=0 the online run is request-for-request
    identical to the drain-the-queue offline run, in both schedulers."""
    cfg, params = _mixtral()
    base_reqs = synthetic_requests(DatasetSpec("a", 5, 9, 4), cfg.vocab_size,
                                   prompt_lens=[9, 6], decode_lens=[2, 4, 6])
    online = synthetic_requests(DatasetSpec("a", 5, 9, 4), cfg.vocab_size,
                                prompt_lens=[9, 6], decode_lens=[2, 4, 6],
                                arrivals=np.zeros(5))
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    for sched in ("static", "continuous"):
        drain = serve_dataset(cfg, params, base_reqs, plan, 4, scheduler=sched)
        live = serve_dataset(cfg, params, online, plan, 4, scheduler=sched)
        assert len(drain.request_results) == len(live.request_results)
        for a, b in zip(drain.request_results, live.request_results):
            assert a.index == b.index
            assert np.array_equal(a.tokens, b.tokens), (sched, a.index)
        assert live.decode_slot_steps == drain.decode_slot_steps


def test_staggered_arrivals_gate_admission_and_populate_metrics():
    """A staggered trace: late requests cannot be admitted before their
    arrival (first token lands at/after the offset on the virtual clock),
    and a full batch makes queue-wait nonzero."""
    cfg, params = _mixtral()
    gap = 0.15
    reqs = synthetic_requests(DatasetSpec("a", 3, 8, 6), cfg.vocab_size,
                              arrivals=[0.0, 0.0, gap])
    plan = Plan(B=1, b_a=1, b_e=16, omega=0.0)
    rep = serve_dataset(cfg, params, reqs, plan, 6, scheduler="continuous")
    rr = rep.request_results
    assert len(rr) == 3
    # B=1: request 1 arrives at t=0 but must wait for request 0 to drain
    assert rr[1].queue_wait_s > 0
    # the late request's first token is at/after its arrival offset
    late = rr[2]
    assert late.arrival_s == gap
    assert late.ttft_s >= 0 and late.queue_wait_s >= 0
    assert late.ttft_s + late.arrival_s >= gap        # absolute clock time
    for r in rr:
        assert r.tpot_s > 0


def test_poisson_run_populates_ttft_tpot():
    """ISSUE acceptance: an open-loop Poisson run completes with
    per-request TTFT/TPOT populated in the report."""
    cfg, params = _mixtral()
    times = arrivals.poisson(4, rate=20.0, seed=1)
    assert (np.diff(times) > 0).all()
    reqs = synthetic_requests(DatasetSpec("p", 4, 8, 4), cfg.vocab_size,
                              arrivals=times)
    rep = serve_dataset(cfg, params, reqs,
                        Plan(B=2, b_a=2, b_e=16, omega=0.0), 4,
                        scheduler="continuous")
    assert len(rep.request_results) == 4
    for r in rep.request_results:
        assert np.isfinite(r.ttft_s) and r.ttft_s > 0
        assert np.isfinite(r.tpot_s) and r.tpot_s > 0
        assert r.queue_wait_s >= 0
    assert rep.ttft_percentile(95) >= rep.ttft_percentile(50) > 0
    assert rep.mean_tpot_s > 0


def test_arrivals_module_validation():
    with pytest.raises(ValueError, match="rate"):
        arrivals.poisson(4, rate=0.0)
    with pytest.raises(ValueError, match=">= 0"):
        arrivals.trace([0.0, -1.0])
    with pytest.raises(ValueError, match="entries"):
        arrivals.assign([Request(np.zeros(4, np.int32), 2)] * 3, [0.0, 0.1])
    assert np.allclose(arrivals.uniform(3, 0.5, start=1.0), [1.0, 1.5, 2.0])


def test_submit_rejects_oversized_and_never_fitting_requests():
    """Lifecycle-API twins of the wrapper's upfront ValueErrors."""
    from dataclasses import replace as dc_replace

    from repro.core import workload as W
    from repro.core.hardware import A5000_C2

    cfg, params = _mixtral()
    plan = Plan(B=1, b_a=1, b_e=8, omega=0.0)
    server = Server(cfg, params, plan, serve=ServeConfig(max_seq=16))
    with pytest.raises(ValueError, match="max_seq"):
        server.submit(Request(np.zeros(30, np.int32), 4))
    need = W.kv_bytes_per_seq(cfg, 40)
    hw = dc_replace(A5000_C2, host_mem_bytes=W.model_bytes(cfg) + 0.5 * need)
    gated = Server(cfg, params, plan,
                   serve=ServeConfig(scheduler="continuous", hw=hw))
    with pytest.raises(ValueError, match="Eq. 2"):
        gated.submit(Request(np.zeros(36, np.int32), 4))
    # a NaN arrival would never compare due and spin run() forever
    with pytest.raises(ValueError, match="arrival_s"):
        server.submit(Request(np.zeros(4, np.int32), 2,
                              arrival_s=float("nan")))


def test_chunked_steps_match_per_tick_both_schedulers():
    """Fused multi-token Server.step chunks are request-for-request
    token-identical to per-tick stepping in BOTH scheduler modes, with
    identical slot-step/waste accounting (chunks end exactly at finish
    boundaries, so no scheduling event ever moves)."""
    cfg, params = _mixtral()
    p_lens, d_lens = [9, 12, 5, 7, 11, 6], [6, 14, 3, 9, 22, 5]

    def requests():
        rng = np.random.default_rng(3)
        return [Request(rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                        d, sampling=(SamplingParams(temperature=0.7, seed=i)
                                     if i % 2 else None))
                for i, (n, d) in enumerate(zip(p_lens, d_lens))]

    for sched in ("static", "continuous"):
        reports = {}
        for chunk in (1, 8):
            plan = Plan(B=4, b_a=4, b_e=64, omega=0.0, decode_chunk=chunk)
            srv = Server(cfg, params, plan,
                         ServeConfig(scheduler=sched, decode_len=8,
                                     max_seq=40))
            for r in requests():
                srv.submit(r)
            reports[chunk] = srv.run()
        a, b = reports[1], reports[8]
        for x, y in zip(a.request_results, b.request_results):
            assert np.array_equal(x.tokens, y.tokens), (sched, x.index)
        assert a.decode_slot_steps == b.decode_slot_steps, sched
        assert a.wasted_slot_steps == b.wasted_slot_steps, sched


def test_chunking_disabled_with_eos_and_identical_results():
    """An eos_id makes finishes unpredictable: _chunk_T degrades to
    per-tick stepping (no behavior change vs decode_chunk=1)."""
    cfg, params = _mixtral()
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(1, cfg.vocab_size, 6).astype(np.int32), 12)
            for _ in range(3)]
    outs = []
    for chunk in (1, 8):
        plan = Plan(B=3, b_a=3, b_e=64, omega=0.0, decode_chunk=chunk)
        srv = Server(cfg, params, plan,
                     ServeConfig(decode_len=12, eos_id=0, max_seq=24))
        for r in reqs:
            srv.submit(Request(r.prompt.copy(), r.decode_len))
        outs.append(srv.run())
    for x, y in zip(outs[0].request_results, outs[1].request_results):
        assert np.array_equal(x.tokens, y.tokens)


def test_chunked_steps_match_per_tick_under_capacity_drops():
    """Free slots + a capacity-starved plan (b_e=1 forces routed drops that
    couple rows through the grouped dispatch): chunked stepping must still
    match per-tick, because dead rows hold their stale token/position
    inside the chunk exactly like per-tick stepping holds a free slot."""
    cfg, params = _mixtral()
    rng = np.random.default_rng(5)
    # the shortest request finishes early and frees its slot with an empty
    # queue, so later chunks decode with a dead row in the batch
    reqs = [Request(rng.integers(0, cfg.vocab_size, n).astype(np.int32), d)
            for n, d in zip([8, 6, 10], [3, 9, 6])]
    results = {}
    for chunk in (1, 4):
        plan = Plan(B=4, b_a=4, b_e=1, omega=0.0, decode_chunk=chunk)
        srv = Server(cfg, params, plan,
                     ServeConfig(scheduler="continuous", decode_len=9,
                                 max_seq=24))
        for r in reqs:
            srv.submit(Request(r.prompt.copy(), r.decode_len))
        results[chunk] = srv.run()
    for x, y in zip(results[1].request_results, results[4].request_results):
        assert np.array_equal(x.tokens, y.tokens), x.index
    assert results[4].expert_tokens_dropped == results[1].expert_tokens_dropped
