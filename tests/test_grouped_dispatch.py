"""Grouped-expert dispatch: the engine's vectorized MoE stage.

Covers the PR's contract:
* grouped vs per-expert-loop engines are token-for-token identical when the
  per-expert capacity ``b_e`` admits every routed token (loop = oracle);
* capacity overflow drops are counted in ``EngineStats`` and never crash;
* the XLA einsum fallback of ``kernels.ops.grouped_expert_ffn`` agrees with
  the Pallas kernel oracle (kernels/ref.py) and with the interpret-mode
  Pallas kernel itself;
* a decode step issues exactly one grouped launch per MoE layer;
* prefill can share the same grouped implementation via
  ``ShardCtx(moe_dispatch='grouped')``.
"""
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.kernels import ops, ref
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.sharding.specs import ShardCtx

KEY = jax.random.PRNGKey(0)
B, S, DEC = 6, 16, 8


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "mixtral-8x7b"])
def test_grouped_matches_loop_token_for_token(arch):
    """The acceptance bar: grouped generate == loop-oracle generate."""
    cfg, params, toks = _setup(arch)
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.0)     # capacity B: no drops
    eng_g = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                                 expert_path="grouped")
    eng_l = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                                 expert_path="loop")
    out_g = eng_g.generate(toks, DEC)
    out_l = eng_l.generate(toks, DEC)
    assert jnp.array_equal(out_g, out_l), (
        float(jnp.mean((out_g == out_l).astype(jnp.float32)))
    )
    assert eng_g.stats.expert_tokens_dropped == 0
    # grouped issues one launch per MoE layer per decode step; the loop
    # oracle issues at least one per non-empty expert
    n_moe = sum(1 for _, f, _ in eng_g.layers if f == "moe")
    assert eng_g.stats.expert_launches == n_moe * (DEC - 1)
    assert eng_l.stats.expert_launches >= eng_g.stats.expert_launches


def test_capacity_overflow_is_counted():
    """b_e below the routed load drops token-copies, visibly in stats."""
    cfg, params, toks = _setup("olmoe-1b-7b")
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=1, omega=0.0), max_seq=S + DEC
    )
    out = eng.generate(toks, DEC)                  # also syncs stats
    assert out.shape == (B, DEC)
    n_moe = sum(1 for _, f, _ in eng.layers if f == "moe")
    routed = n_moe * (DEC - 1) * B * cfg.experts_per_token
    assert eng.stats.expert_tokens_dropped > 0
    assert eng.stats.expert_tokens + eng.stats.expert_tokens_dropped == routed
    # capacity 1 x E experts bounds what can be kept per layer-step
    assert eng.stats.expert_tokens <= n_moe * (DEC - 1) * cfg.num_experts


def test_decode_step_no_host_routing_sync(monkeypatch):
    """The grouped decode step never materializes routing on the host: the
    engine module's numpy binding is replaced by a tripwire for one step."""
    from repro.core import engine as engine_mod

    cfg, params, toks = _setup("mixtral-8x7b")
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=B, omega=0.0), max_seq=S + DEC
    )
    eng.prefill(toks)

    class _NoHostNumpy:
        def __getattr__(self, name):
            raise AssertionError(f"host numpy used in decode_step: np.{name}")

    monkeypatch.setattr(engine_mod, "np", _NoHostNumpy())
    eng.decode_step(toks[:, 0], S)                 # must not touch numpy


def test_xla_fallback_matches_ref_and_pallas():
    """ops.grouped_expert_ffn: einsum fallback vs kernels/ref.py oracle and
    vs the Pallas kernel in interpret mode."""
    E, C, D, F = 4, 128, 256, 128
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, D)) * 0.3).astype(jnp.bfloat16)
    wg = (jax.random.normal(ks[1], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wu = (jax.random.normal(ks[2], (E, D, F)) * 0.05).astype(jnp.bfloat16)
    wd = (jax.random.normal(ks[3], (E, F, D)) * 0.05).astype(jnp.bfloat16)
    fallback = ops.grouped_expert_ffn(x, wg, wu, wd, use_kernel=False)
    oracle = ref.expert_ffn_ref(x, wg, wu, wd)
    pallas = ops.expert_ffn(x, wg, wu, wd, interpret=True)
    d_ref = jnp.max(jnp.abs(fallback.astype(jnp.float32) -
                            oracle.astype(jnp.float32)))
    d_pal = jnp.max(jnp.abs(fallback.astype(jnp.float32) -
                            pallas.astype(jnp.float32)))
    assert float(d_ref) < 0.05 * D ** 0.5, d_ref
    assert float(d_pal) < 0.05 * D ** 0.5, d_pal
    # on CPU the dispatch wrapper must select the fallback
    auto = ops.grouped_expert_ffn(x, wg, wu, wd)
    assert jnp.array_equal(auto, fallback)


def test_grouped_dispatch_drop_accounting_exact():
    cfg = replace(get_config("olmoe-1b-7b", smoke=True))
    p = moe_mod.init_moe_params(cfg, KEY)
    xt = (jax.random.normal(KEY, (32, cfg.d_model)) * 0.3).astype(jnp.bfloat16)
    gates, idx, _ = moe_mod.route(cfg, p["router"], xt)
    for cap in (1, 4, 32):
        y, kept, dropped, load = moe_mod.grouped_dispatch(
            cfg, xt, gates, idx,
            p["experts_w_gate"], p["experts_w_up"], p["experts_w_down"], cap,
        )
        assert y.shape == xt.shape
        assert int(kept) + int(dropped) == 32 * cfg.experts_per_token
        # per-expert kept count can never exceed the capacity
        assert int(kept) <= cap * cfg.num_experts
        # the routed-load histogram counts every copy, PRE-capacity
        assert load.shape == (cfg.num_experts,)
        assert int(load.sum()) == 32 * cfg.experts_per_token


def test_grouped_dispatch_rejected_on_mesh():
    """moe_dispatch='grouped' is a single-device path: on a mesh with a
    model axis it must error, not silently fall back to psum."""
    cfg = get_config("olmoe-1b-7b", smoke=True)
    p = moe_mod.init_moe_params(cfg, KEY)
    x = jnp.zeros((2, 8, cfg.d_model), jnp.bfloat16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                   moe_dispatch="grouped")
    with pytest.raises(ValueError, match="grouped"):
        moe_mod.moe_apply(cfg, p, x, ctx)


def test_serve_report_surfaces_drops():
    """serve_dataset folds the device-side drop counters into the report."""
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("tiny", 4, 8, 4), cfg.vocab_size)
    rep = serve_dataset(cfg, params, reqs,
                        Plan(B=4, b_a=2, b_e=1, omega=0.0), 4)
    assert rep.expert_tokens_dropped > 0
    rep_ok = serve_dataset(cfg, params, reqs,
                           Plan(B=4, b_a=2, b_e=4, omega=0.0), 4)
    assert rep_ok.expert_tokens_dropped == 0


def test_grouped_prefill_shares_decode_path():
    """moe_apply with ShardCtx(moe_dispatch='grouped') routes the reference
    forward through the engine's grouped implementation."""
    cfg = replace(get_config("olmoe-1b-7b", smoke=True), capacity_factor=64.0)
    p = moe_mod.init_moe_params(cfg, KEY)
    x = (jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3).astype(jnp.bfloat16)
    y_grp, _ = moe_mod.moe_apply(cfg, p, x, ShardCtx(moe_dispatch="grouped"))
    y_loc, _ = moe_mod.moe_apply_local(cfg, p, x)
    d = jnp.max(jnp.abs(y_grp.astype(jnp.float32) - y_loc.astype(jnp.float32)))
    assert float(d) < 0.03, d
    # and the engine flag exercises it end-to-end at prefill
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (4, 8), 0, cfg.vocab_size)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=2, b_e=4, omega=0.0), max_seq=16,
        grouped_prefill=True,
    )
    ref_eng = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=2, b_e=4, omega=0.0), max_seq=16,
    )
    lg = eng.prefill(toks)
    lr = ref_eng.prefill(toks)
    scale = float(jnp.max(jnp.abs(lr.astype(jnp.float32)))) + 1e-6
    d = float(jnp.max(jnp.abs(lg.astype(jnp.float32) -
                              lr.astype(jnp.float32)))) / scale
    assert d < 0.05, d
