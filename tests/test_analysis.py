"""Static-analysis + sanitizer subsystem (ISSUE 7).

The PR's contract, exercised rule class by rule class with a DELIBERATE
violation of each: the AST lint catches every MG-rule pattern (and the
repo itself lints clean); the runtime sanitizer raises on an unplanned
transfer inside a decode region and passes planned ``allowed()`` scopes;
``steady()`` raises when a registered jit compiles mid-steady-state; the
donation checker verifies compiled-HLO aliasing for the real donated
engine launches and catches a dropped donation; the stale-buffer poisoner
makes retained cache references fail loudly.  The tier-1 serving test
runs full ``Server.run()`` lifecycles — both schedulers x fused /
streamed / paged Mode B — under ``sanitize(strict=True)`` with zero
unplanned transfers and zero steady-state retraces.
"""
import functools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import donation, lint, registry, runtime
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.data.datasets import DatasetSpec, synthetic_requests
from repro.models import model as M
from repro.serving import kvcache
from repro.serving.cache import CacheConfig
from repro.serving.sampling import BatchSampler
from repro.serving.server import ServeConfig, Server, StreamConfig

KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")


def _mixtral():
    cfg = get_config("mixtral-8x7b", smoke=True)
    return cfg, M.init_params(cfg, KEY)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# AST lint: one deliberate violation per rule
# ---------------------------------------------------------------------------
def test_lint_mg101_host_sync_in_hot_path():
    src = textwrap.dedent("""
        import numpy as np
        from repro.analysis import hot_path

        @hot_path
        def tick(x):
            a = np.asarray(x)
            b = x.item()
            c = float(x)
            x.block_until_ready()
            return a, b, c
    """)
    found = lint.check_source(src, "t.py", "core/t.py")
    assert _rules(found) == ["MG101"] and len(found) == 4


def test_lint_mg101_ignores_cold_functions():
    src = "import numpy as np\ndef cold(x):\n    return np.asarray(x)\n"
    assert lint.check_source(src, "t.py", "core/t.py") == []


def test_lint_mg102_jit_in_loop():
    src = textwrap.dedent("""
        import jax
        def run(xs):
            for x in xs:
                f = jax.jit(lambda a: a + 1)
                x = f(x)
            return x
    """)
    assert _rules(lint.check_source(src, "t.py", "core/t.py")) == ["MG102"]


def test_lint_mg103_frozen_config_mutation():
    src = textwrap.dedent("""
        def tweak(cfg, plan):
            cfg.num_layers = 4
            plan.B += 1
            object.__setattr__(cfg, "d_model", 8)
    """)
    found = lint.check_source(src, "t.py", "core/t.py")
    assert _rules(found) == ["MG103"] and len(found) == 3


def test_lint_mg103_allows_construction_scopes():
    src = textwrap.dedent("""
        class C:
            def __init__(self, cfg):
                self.cfg = cfg
        def __post_init__(self):
            object.__setattr__(self, "x", 1)
    """)
    assert lint.check_source(src, "t.py", "core/t.py") == []


def test_lint_mg104_update_slice_without_donation():
    src = textwrap.dedent("""
        import functools, jax
        from jax import lax

        @functools.partial(jax.jit)
        def write(cache, v, i):
            return lax.dynamic_update_slice(cache, v, (i,))

        @functools.partial(jax.jit, donate_argnames=("cache",))
        def write_ok(cache, v, i):
            return lax.dynamic_update_slice(cache, v, (i,))
    """)
    found = lint.check_source(src, "t.py", "core/t.py")
    assert _rules(found) == ["MG104"] and len(found) == 1


def test_lint_mg105_device_put_outside_window():
    src = "import jax\ndef f(x):\n    return jax.device_put(x)\n"
    assert _rules(lint.check_source(src, "t.py", "core/t.py")) == ["MG105"]
    # the StreamWindow modules own the planned htod path
    assert lint.check_source(src, "t.py", "serving/weights.py") == []
    assert lint.check_source(src, "t.py", "serving/cache.py") == []


def test_lint_allowlist_suppression_and_mg106():
    ok = textwrap.dedent("""
        import numpy as np
        from repro.analysis import hot_path
        @hot_path
        def tick(x):
            return np.asarray(x)  # lint: allow[MG101] planned readback
    """)
    assert lint.check_source(ok, "t.py", "core/t.py") == []
    # a suppression without a justification is itself a violation
    bare = ok.replace(" planned readback", "")
    assert _rules(lint.check_source(bare, "t.py", "core/t.py")) == ["MG106"]


def test_lint_repo_is_clean():
    assert lint.lint_paths([SRC]) == []


def test_lint_cli_blocking_exit_codes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(SRC, os.pardir)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", SRC],
        env=env, capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n\ndef f(x):\n    return jax.device_put(x)\n")
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        env=env, capture_output=True, text=True,
    )
    assert out.returncode == 1 and "MG105" in out.stdout


# ---------------------------------------------------------------------------
# Runtime sanitizer: transfer guard, planned scopes, steady-state retraces
# ---------------------------------------------------------------------------
def test_decode_region_rejects_unplanned_transfer():
    x = jnp.arange(4)
    with analysis.sanitize(strict=True):
        with runtime.decode_region():
            with pytest.raises(Exception, match="[Dd]isallowed"):
                _ = x + 1           # implicit Python-scalar h2d mid-tick


def test_allowed_scope_permits_and_counts():
    x = jnp.arange(4)
    with analysis.sanitize(strict=True) as san:
        with runtime.decode_region():
            with analysis.allowed("test-tag"):
                y = x + 1
        np.testing.assert_array_equal(np.asarray(y), np.arange(1, 5))
    assert san.planned["test-tag"] == 1
    assert san.report()["planned_transfers"]["test-tag"] == 1


def test_decode_region_without_sanitizer_is_noop(monkeypatch):
    # Disarm the env-armed ambient sanitizer (CI sets REPRO_SANITIZE=strict)
    # so this really exercises the no-sanitizer path.
    monkeypatch.setattr(runtime, "_AMBIENT", None)
    monkeypatch.setattr(runtime, "_AMBIENT_INIT", True)
    x = jnp.arange(4)
    with runtime.decode_region():
        assert int(np.asarray(x + 1)[0]) == 1


def test_steady_region_catches_retrace():
    @analysis.register_jit("test.steady_fn")
    @jax.jit
    def f(x):
        return x * 2

    f(jnp.zeros(4))                 # warm one trace
    with analysis.sanitize(strict=True) as san:
        with san.steady():
            f(jnp.zeros(4))         # cached: clean
        with pytest.raises(analysis.RetraceViolation, match="test.steady_fn"):
            with san.steady():
                f(jnp.zeros(8))     # new shape: steady-state retrace
    assert san.steady_retraces["test.steady_fn"] == 1


def test_steady_region_logs_in_nonstrict_mode():
    @analysis.register_jit("test.steady_log_fn")
    @jax.jit
    def f(x):
        return x - 1

    with analysis.sanitize(strict=False) as san:
        with san.steady():
            f(jnp.zeros(3))
    assert san.steady_retraces == {"test.steady_log_fn": 1}


def test_registry_counts_and_keysets():
    counts = registry.compile_counts()
    assert "engine.fused_decode_chunk" in counts
    assert "kvcache.evict" in counts
    ks = registry.TraceKeySet("test.keys")
    assert ks.add(("a", 1)) and not ks.add(("a", 1)) and ks.add(("b",))
    assert ks.count == 2 and registry.keyset_counts()["test.keys"] == 2


def test_evict_retrace_shim_rides_the_registry():
    base = kvcache.evict_retraces()
    cache = [{"k": jnp.zeros((4, 8, 1, 2)), "v": jnp.zeros((4, 8, 1, 2))}]
    cache = kvcache.evict_rows(cache, [1])          # width 8 (maybe seen)
    cache = kvcache.evict_rows(cache, list(range(3)))   # width 8 again
    assert kvcache.evict_retraces() >= max(1, base)
    assert (registry.keyset_counts()["kvcache.evict_rows"]
            == kvcache.evict_retraces())


def test_ambient_env_sanitizer(tmp_path):
    """REPRO_SANITIZE arms a process-wide sanitizer; the report dumps at
    interpreter exit when REPRO_SANITIZE_REPORT is set."""
    report = tmp_path / "san.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(SRC, os.pardir)
    env["REPRO_SANITIZE"] = "strict"
    env["REPRO_SANITIZE_REPORT"] = str(report)
    snippet = (
        "import jax.numpy as jnp\n"
        "from repro.analysis import runtime\n"
        "x = jnp.arange(4)\n"
        "failed = False\n"
        "with runtime.decode_region():\n"
        "    try:\n"
        "        x + 1\n"
        "    except Exception:\n"
        "        failed = True\n"
        "assert failed, 'ambient strict guard did not trip'\n"
        "with runtime.allowed('tag'):\n"
        "    x + 1\n"
    )
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    rep = json.loads(report.read_text())
    assert rep["mode"] == "strict" and rep["planned_transfers"]["tag"] == 1


# ---------------------------------------------------------------------------
# Donation checker + stale-buffer poisoner
# ---------------------------------------------------------------------------
def test_donation_check_confirms_real_aliasing():
    @functools.partial(jax.jit, donate_argnames=("cache",))
    def write(cache, v):
        return cache.at[0].set(v)

    cache, v = jnp.zeros((4, 8)), jnp.ones(8)
    res = donation.check_donation(write, (cache, v), {}, ("cache",),
                                  name="t.write")
    assert res.ok and res.aliased >= 1 and res.donated_leaves == 1
    assert not cache.is_deleted()   # AOT lowering must not consume buffers
    write(cache, v)


def test_donation_check_catches_dropped_donation():
    @functools.partial(jax.jit, donate_argnames=("x",))
    def grow(x):
        return jnp.concatenate([x, x])  # (n,) can never alias (2n,)

    res = donation.check_donation(grow, (jnp.zeros(4),), {}, ("x",),
                                  name="t.grow")
    assert not res.ok and res.dropped


def test_sanitizer_raises_on_dropped_donation():
    @analysis.register_jit("test.bad_donation", donated=("x",))
    @functools.partial(jax.jit, donate_argnames=("x",))
    def grow(x):
        return jnp.concatenate([x, x])

    with analysis.sanitize(strict=True, donation=True):
        with pytest.raises(analysis.DonationViolation, match="bad_donation"):
            grow(jnp.zeros(4))
    # non-strict: recorded, not raised
    with analysis.sanitize(strict=False, donation=True) as san:
        grow(jnp.zeros(6))
    assert [d["ok"] for d in san.donation_checks] == [False]


def test_engine_donated_launches_alias(monkeypatch):
    """The real donated engine launches alias their cache pytrees: run a
    generation under donation checking and assert every intercepted check
    verified (fused decode chunk + eviction are covered by the serving
    test; this covers the per-module attention path too)."""
    cfg, params = _mixtral()
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              cfg.vocab_size)
    with analysis.sanitize(strict=True, donation=True) as san:
        eng = ModuleBatchingEngine(cfg, params,
                                   Plan(B=2, b_a=2, b_e=16, omega=0.0),
                                   max_seq=12, fused_decode=False)
        eng.generate(toks, 3)
    names = {d["name"] for d in san.donation_checks}
    assert "engine.attn_decode" in names
    assert all(d["ok"] for d in san.donation_checks), san.donation_checks


def test_poison_stale_unit():
    with analysis.sanitize(strict=False, poison=True):
        a, b = jnp.arange(4), jnp.arange(5)
        runtime.poison_stale([a, b], [b])
        assert a.is_deleted() and not b.is_deleted()
    # poison off: no-op
    c, d = jnp.arange(4), jnp.arange(5)
    with analysis.sanitize(strict=False, poison=False):
        runtime.poison_stale([c, d], [d])
    assert not c.is_deleted()


def test_poisoner_makes_retained_cache_refs_fail_loudly():
    cfg, params = _mixtral()
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                              cfg.vocab_size)
    with analysis.sanitize(strict=False, poison=True):
        eng = ModuleBatchingEngine(cfg, params,
                                   Plan(B=2, b_a=2, b_e=16, omega=0.0),
                                   max_seq=12)
        out = eng.generate(toks, 2)
        li = next(i for i, (k, _) in enumerate(eng.schema) if k == "attn")
        retained = eng.cache[li]["k"]       # the bug the poisoner catches
        sampler = BatchSampler(2)
        eng.decode_chunk(out[:, -1], jnp.full((2,), 9, jnp.int32), sampler, 1)
        with pytest.raises(RuntimeError):
            np.asarray(retained)
        np.asarray(eng.cache[li]["k"])      # the live buffer still reads


# ---------------------------------------------------------------------------
# Mode B position mirror: one planned readback per tick, not per layer
# ---------------------------------------------------------------------------
def test_paged_decode_pos_mirror_once_per_tick():
    cfg, params = _mixtral()
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                              cfg.vocab_size)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=2, b_a=2, b_e=16, omega=0.0), max_seq=12,
        cache_config=CacheConfig(page_tokens=4, device_pool_bytes=1.0),
    )
    assert eng.pages is None or True  # pages built at init_cache
    eng.prefill(toks)
    assert eng.pages is not None and not eng.pages.fully_resident
    with analysis.sanitize(strict=True) as san:
        eng.decode_step(toks[:, -1], jnp.full((2,), 8, jnp.int32))
    n_attn = sum(1 for k, _ in eng.schema if k == "attn")
    assert n_attn > 1               # the regression needs >1 attn layer
    assert san.planned["decode-pos-host-mirror"] == 1


# ---------------------------------------------------------------------------
# Tier-1 serving: full lifecycles under the strict sanitizer (satellite 3)
# ---------------------------------------------------------------------------
_SERVE_MODES = {
    "fused": {},
    "streamed": {"stream": StreamConfig(stream_weights=True,
                                        resident_bytes=0.0, prefetch=True)},
    "paged-b": {"kv_page_tokens": 4, "device_kv_gb": 1e-6},
}


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
@pytest.mark.parametrize("mode", sorted(_SERVE_MODES))
def test_server_lifecycle_sanitized(scheduler, mode):
    cfg, params = _mixtral()
    opts = dict(_SERVE_MODES[mode])
    stream = opts.pop("stream", None)
    serve = ServeConfig(scheduler=scheduler, decode_len=3, **opts)
    kw = {} if stream is None else {"stream": stream}
    reqs = synthetic_requests(DatasetSpec("t", 3, 8, 3), cfg.vocab_size)
    with analysis.sanitize(strict=True, donation=True) as san:
        server = Server(cfg, params, Plan(B=2, b_a=2, b_e=16, omega=0.0),
                        serve=serve, **kw)
        handles = [server.submit(r) for r in reqs]
        # warm pass: trace every module shape this workload uses
        while server.step():
            pass
        for h in handles:
            assert len(h.tokens) == 3
        # steady pass: the identical workload must hit every cached trace
        with san.steady():
            h2 = [server.submit(r) for r in reqs]
            while server.step():
                pass
        server.finalize()
        for h in h2:
            assert len(h.tokens) == 3
    rep = san.report()
    assert rep["steady_retraces"] == {}
    assert all(d["ok"] for d in rep["donation_checks"]), rep["donation_checks"]
    assert rep["planned_transfers"]["token-readback"] >= 1
