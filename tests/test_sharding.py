"""Sharding rules + 1-device-mesh numerical equivalence."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import model as M
from repro.sharding.specs import ShardCtx, cache_shardings, param_shardings

KEY = jax.random.PRNGKey(0)


def _ctx_1dev():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")


def test_param_shardings_cover_all_leaves():
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = jax.eval_shape(lambda: M.init_params(cfg, KEY))
    specs = param_shardings(_ctx_1dev(), params, zero1=True)
    n_params = len(jax.tree.leaves(params))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: x is None))
    assert n_params == n_specs


def test_param_shardings_divisibility_respected():
    """On the production mesh every spec divides its dim."""
    import numpy as np

    cfg = get_config("mixtral-8x7b")      # 8 experts vs model=16: fallback path
    params = jax.eval_shape(lambda: M.init_params(cfg, KEY))
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
    specs = param_shardings(ctx, params, zero1=True)

    def check(leaf, sharding):
        spec = sharding.spec
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            size = 1
            axes = (ax,) if isinstance(ax, str) else ax
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (leaf.shape, spec)

    jax.tree.map(check, params, specs)


def test_expert_fallback_tensor_parallel():
    """mixtral 8 experts % model=16 != 0 => F-dim sharding instead."""
    import numpy as np

    cfg = get_config("mixtral-8x7b")
    params = jax.eval_shape(lambda: M.init_params(cfg, KEY))
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
    specs = param_shardings(ctx, params, zero1=False)
    flat = {
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: x is None
        )[0]
    }
    gate_spec = next(v for k, v in flat.items() if "experts_w_gate" in k)
    assert gate_spec.spec[0] is None          # experts replicated
    assert gate_spec.spec[-1] == "model"      # hidden dim sharded


def test_forward_with_mesh_matches_without():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    base, _, _ = M.forward(cfg, params, toks)
    ctx = _ctx_1dev()
    sharded, _, _ = M.forward(cfg, params, toks, ctx=ctx)
    d = jnp.max(jnp.abs(base.astype(jnp.float32) -
                        sharded.astype(jnp.float32)))
    assert float(d) < 0.05, d


def test_cache_shardings_structure():
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 2, 32))
    specs = cache_shardings(_ctx_1dev(), cache)
    assert len(jax.tree.leaves(cache)) == len(
        jax.tree.leaves(specs, is_leaf=lambda x: x is None)
    )
