"""Roofline analysis module: analytic terms and report assembly."""
import json
import os

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    memory_bytes_per_device,
    model_flops,
)


def test_memory_components_positive():
    for arch in ("olmoe-1b-7b", "jamba-1.5-large-398b", "qwen2-1.5b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            m = memory_bytes_per_device(cfg, shape)
            assert m["total"] > 0
            assert all(v >= 0 for v in m.values())


def test_model_flops_train_is_6nd():
    cfg = get_config("qwen2-1.5b")
    shape = SHAPES["train_4k"]
    n = cfg.param_counts()["active"]
    assert model_flops(cfg, shape) == pytest.approx(
        6 * n * shape.global_batch * shape.seq_len
    )


def test_model_flops_moe_uses_active_params():
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert model_flops(moe, SHAPES["train_4k"]) < 0.3 * (
        6 * moe.param_counts()["total"]
        * SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
    )


def test_decode_flops_per_token():
    cfg = get_config("qwen2-1.5b")
    d = SHAPES["decode_32k"]
    # decode: 2 * N_active * batch (one token each)
    assert model_flops(cfg, d) == pytest.approx(
        2 * cfg.param_counts()["active"] * d.global_batch
    )


def test_weight_stationary_reduces_memory_term():
    cfg = get_config("olmoe-1b-7b")
    d = SHAPES["decode_32k"]
    fsdp = memory_bytes_per_device(cfg, d, "fsdp")["total"]
    tp = memory_bytes_per_device(cfg, d, "tp")["total"]
    assert tp < fsdp


def test_dryrun_reports_parse_if_present():
    rep_dir = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")
    if not os.path.isdir(rep_dir):
        pytest.skip("no dry-run reports generated")
    files = [f for f in os.listdir(rep_dir) if f.endswith(".json")]
    if not files:
        pytest.skip("no dry-run reports generated")
    ok = 0
    for f in files:
        with open(os.path.join(rep_dir, f)) as fh:
            d = json.load(fh)
        assert d["status"] in ("ok", "skipped", "failed")
        if d["status"] == "ok":
            ok += 1
            assert d.get("dot_flops_per_device") is not None
            assert d.get("collective_bytes") is not None
    assert ok > 0
