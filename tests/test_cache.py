"""Paged tiered KV cache: page table, host tier, prefix reuse (ISSUE 6).

The PR's contract: paged generation — fully device-resident (Mode A),
host-tier streamed (Mode B), and prefix-cache-admitted — is token-for-token
identical to the contiguous baseline; a config whose KV exceeds the device
pool budget but fits the host still serves; prefix hits skip the shared
span's prefill launches entirely.  (The hypothesis paged==contiguous
property lives in test_properties.py, the only module allowed to import
hypothesis.)
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine, dispatch_count
from repro.core.hardware import A5000_C2
from repro.models import model as M
from repro.serving.cache import CacheConfig, KVPageTable, PrefixStore
from repro.serving.kvcache import evict_retraces, evict_rows
from repro.serving.scheduler import Request, ServeConfig, Server, serve_dataset

KEY = jax.random.PRNGKey(0)
B, S, DEC = 4, 12, 6


def _setup(arch="mixtral-8x7b", **over):
    cfg = get_config(arch, smoke=True)
    if over:
        cfg = replace(cfg, **over)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


def _generate(cfg, params, toks, omega=0.0, **engine_kw):
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=B, omega=omega), max_seq=S + DEC,
        **engine_kw,
    )
    out = eng.generate(toks, DEC)
    return np.asarray(out), eng


def _schema(cfg):
    return [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.num_layers)]


# ---------------------------------------------------------------------------
# CacheConfig / KVPageTable unit behavior
# ---------------------------------------------------------------------------
def test_cache_config_validation():
    assert not CacheConfig().enabled
    assert CacheConfig(page_tokens=8).enabled
    with pytest.raises(AssertionError):
        CacheConfig(page_tokens=-1)
    with pytest.raises(AssertionError):
        CacheConfig(page_tokens=0, prefix_cache=True)


def test_page_table_alloc_free_and_frame_encoding():
    """ensure_rows/free_rows recycle frames; gather_indices remaps device
    frame f -> f, host frame h -> P+1+h, unallocated -> the null sink P."""
    cfg, _, _ = _setup()
    # budget for exactly half the frames -> Mode B with a real device pool
    probe = KVPageTable(cfg, _schema(cfg), B, S + DEC, CacheConfig(page_tokens=4))
    half = probe.total_frames // 2
    pt = KVPageTable(cfg, _schema(cfg), B, S + DEC,
                     CacheConfig(page_tokens=4,
                                 device_pool_bytes=half * probe.frame_bytes))
    assert pt.device_frames == half and pt.host_frames == probe.total_frames - half
    assert not pt.fully_resident
    P = pt.device_frames
    # unallocated rows gather the null frame
    assert (pt.gather_indices([0, 1]) == P).all()
    pt.ensure_rows([0, 1], prefer_host=[False, True])
    g = pt.gather_indices([0, 1])
    assert ((g[0] < P) | (g[0] > P)).all() and (g != P).all()
    assert (g[1] > P).all()                       # host rows remap past null
    # re-ensuring a live row keeps its placement
    before = pt.page_map[0].copy()
    pt.ensure_rows([0], prefer_host=[True])
    assert np.array_equal(pt.page_map[0], before)
    # free returns every frame; the map is clean and realloc succeeds
    pt.free_rows([0, 1])
    assert (pt.page_map[:2] == -1).all()
    pt.ensure_rows(list(range(B)), prefer_host=[False] * B)
    assert (pt.page_map >= 0).all()
    assert "frames device" in pt.describe()


def test_page_table_spills_across_tiers():
    """When the preferred tier runs dry, allocation spills into the other
    tier instead of failing (the ω rows vs page placement decoupling)."""
    cfg, _, _ = _setup()
    pt = KVPageTable(cfg, _schema(cfg), B, S + DEC,
                     CacheConfig(page_tokens=4, device_pool_bytes=1.0))
    assert pt.device_frames == 0                  # everything is host-tier
    pt.ensure_rows(list(range(B)), prefer_host=[False] * B)  # all must spill
    assert (pt.page_map >= 0).all()


def test_mode_a_table_is_bookkeeping_only():
    cfg, _, _ = _setup()
    pt = KVPageTable(cfg, _schema(cfg), B, S + DEC, CacheConfig(page_tokens=8))
    assert pt.fully_resident
    assert not pt.pool_k and not pt.host_k        # no pools materialized
    assert pt.take_counters() == (0, 0, 0.0)


# ---------------------------------------------------------------------------
# Exactness: paged == contiguous, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["mixtral-8x7b",           # attention + MoE
                                  "h2o-danube-1.8b"])       # sliding window
def test_paged_resident_generate_matches_contiguous(arch):
    """Mode A: the engine keeps its contiguous buffers and the fused decode
    path — paging is free when every frame fits the device pool."""
    cfg, params, toks = _setup(arch)
    ref, ref_eng = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks,
                         cache_config=CacheConfig(page_tokens=8))
    assert np.array_equal(ref, got)
    assert eng.pages is not None and eng.pages.fully_resident
    assert eng.fused_eligible() == ref_eng.fused_eligible()
    assert eng.stats.kv_htod_bytes == 0


@pytest.mark.parametrize("omega", [0.0, 0.5])
@pytest.mark.parametrize("arch", ["mixtral-8x7b", "h2o-danube-1.8b"])
def test_paged_host_tier_generate_matches_contiguous(arch, omega):
    """Mode B: every page frame host-side, streamed through the prefetch
    window — still bit-identical, with real page traffic, under both pure
    device attention and the ω host-attention split."""
    cfg, params, toks = _setup(arch)
    ref, _ = _generate(cfg, params, toks, omega=omega)
    got, eng = _generate(cfg, params, toks, omega=omega,
                         cache_config=CacheConfig(page_tokens=8,
                                                  device_pool_bytes=1.0))
    assert np.array_equal(ref, got), (arch, omega)
    assert not eng.pages.fully_resident
    assert eng.stats.kv_htod_bytes > 0
    assert eng.stats.kv_dtoh_bytes > 0


def test_paged_mixed_tier_generate_matches_contiguous():
    """A device pool covering only half the frames: rows straddle tiers and
    decode writes spill both ways."""
    cfg, params, toks = _setup()
    probe = KVPageTable(cfg, _schema(cfg), B, S + DEC, CacheConfig(page_tokens=4))
    budget = (probe.total_frames // 2) * probe.frame_bytes
    ref, _ = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks,
                         cache_config=CacheConfig(page_tokens=4,
                                                  device_pool_bytes=budget))
    assert np.array_equal(ref, got)
    assert 0 < eng.pages.device_frames < eng.pages.total_frames


def test_paged_host_tier_disables_fused_path():
    """The path-selection contract: host-tier pages force the per-layer
    loop (the page stream needs a layer boundary to hide behind), exactly
    like streamed weights."""
    cfg, params, toks = _setup()
    _, eng = _generate(cfg, params, toks,
                       cache_config=CacheConfig(page_tokens=8,
                                                device_pool_bytes=1.0))
    assert not eng.fused_eligible()
    assert eng.stats.fused_dispatches == 0


# ---------------------------------------------------------------------------
# Serving: device budget gating + the host-tier acceptance case
# ---------------------------------------------------------------------------
def _requests(cfg, lens, dec=DEC, seed=3, shared=0):
    rng = np.random.default_rng(seed)
    pre = [int(t) for t in rng.integers(5, cfg.vocab_size - 5, size=shared)]
    return [
        Request(prompt=pre + [int(t) for t in
                              rng.integers(5, cfg.vocab_size - 5, size=n)],
                decode_len=dec)
        for n in lens
    ]


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_kv_exceeding_device_budget_serves_from_host(scheduler):
    """ISSUE acceptance: a config whose KV cannot fit the device pool
    budget (device_kv_gb ~ 0) but fits host memory serves successfully and
    returns the contiguous baseline's tokens."""
    cfg, params, _ = _setup()
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    lens = [8, 6, 9, 7]
    ref = serve_dataset(cfg, params, _requests(cfg, lens), plan, DEC,
                        scheduler=scheduler, max_seq=S + DEC)
    rep = serve_dataset(cfg, params, _requests(cfg, lens), plan, DEC,
                        scheduler=scheduler, max_seq=S + DEC,
                        kv_page_tokens=8, device_kv_gb=1e-9)
    for a, b in zip(ref.request_results, rep.request_results):
        assert np.array_equal(a.tokens, b.tokens), a.index
    assert rep.kv_htod_gb > 0.0


def test_serve_config_from_plan_sizes_server_up_front():
    """from_plan: the planner fixes max_seq/max_batch before the first
    submit instead of sizing from the first-step queue."""
    cfg, params, _ = _setup()
    sc = ServeConfig.from_plan(cfg, A5000_C2, ctx=64, scheduler="continuous",
                               B=4, decode_len=4, kv_page_tokens=8)
    assert sc.plan is not None
    assert sc.max_seq == 64 and sc.max_batch == sc.plan.B
    assert 1 <= sc.max_batch <= 4
    srv = Server(cfg, params, serve=sc)
    for r in _requests(cfg, [6, 8]):
        r.decode_len = 4
        srv.submit(r)
    rep = srv.run()
    assert len(rep.request_results) == 2


def test_serve_config_prefix_cache_requires_paging():
    with pytest.raises(AssertionError):
        ServeConfig(prefix_cache=True)


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------
def test_prefix_store_keys_lru_and_support():
    ps = PrefixStore(page_tokens=4, entries=2)
    assert ps.key(np.arange(4)) is None           # no page strictly inside
    key, pspan = ps.key(np.arange(9))
    assert pspan == 8 and key == np.arange(8, dtype=np.int32).tobytes()
    assert ps.get(key) is None and ps.misses == 1
    ps.put(key, ["a"])
    assert ps.get(key) == ["a"] and ps.hits == 1
    ps.put(b"k2", ["b"])
    ps.put(b"k3", ["c"])                          # evicts the LRU entry
    assert len(ps._store) == 2 and ps.hit_rate == 0.5
    assert PrefixStore.supported(get_config("mixtral-8x7b", smoke=True))
    assert not PrefixStore.supported(get_config("h2o-danube-1.8b", smoke=True))


@pytest.mark.parametrize("device_pool", [None, 1.0])
def test_prefix_hit_admission_is_exact_and_skips_prefill(device_pool):
    """A prefix hit replays stored page rows and runs ONLY the suffix
    prefill: L+2 module launches (embed + one per layer + head), whatever
    the prefix length — and the admitted sequence decodes bit-identically
    to a cold prefill."""
    cfg, params, _ = _setup()
    cc = CacheConfig(page_tokens=4, device_pool_bytes=device_pool,
                     prefix_cache=True)
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    rng = np.random.default_rng(11)
    for npre in (8, 12):                          # two prefix lengths
        pre = [int(t) for t in rng.integers(5, cfg.vocab_size - 5, size=npre)]
        pa = pre + [int(t) for t in rng.integers(5, cfg.vocab_size - 5, size=2)]
        pb = pre + [int(t) for t in rng.integers(5, cfg.vocab_size - 5, size=3)]
        ref = serve_dataset(cfg, params, [Request(prompt=list(p), decode_len=4)
                                          for p in (pa, pb)],
                            plan, 4, max_seq=npre + 8, kv_page_tokens=4)
        eng = ModuleBatchingEngine(cfg, params, plan, max_seq=npre + 8,
                                   cache_config=cc)
        eng.init_cache(2)
        eng.prefill_slots(jnp.asarray(pa)[None, :], [0])
        kvs = eng.read_prefix_rows(0, npre)
        d0 = dispatch_count()
        logits = eng.prefill_prefix_hit(1, pb, kvs, npre)
        assert dispatch_count() - d0 == cfg.num_layers + 2, npre
        tok = int(np.argmax(np.asarray(logits[0])))
        assert tok == int(ref.request_results[1].tokens[..., 0].reshape(-1)[0])


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_prefix_cache_serving_matches_cold_and_counts_hits(scheduler):
    cfg, params, _ = _setup()
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    reqs = lambda: _requests(cfg, [3, 2, 4], shared=9, seed=5)
    ref = serve_dataset(cfg, params, reqs(), plan, DEC, scheduler=scheduler,
                        max_seq=24)
    # page 8: every prompt (lengths 12, 11, 13) keys at pspan=8, inside
    # the 9-token shared span — one stored prefix serves them all
    rep = serve_dataset(cfg, params, reqs(), plan, DEC, scheduler=scheduler,
                        max_seq=24, kv_page_tokens=8, prefix_cache=True)
    for a, b in zip(ref.request_results, rep.request_results):
        assert np.array_equal(a.tokens, b.tokens), (scheduler, a.index)
    assert rep.prefix_hits >= 1
    assert 0.0 < rep.prefix_hit_rate <= 1.0


def test_prefix_cache_silently_disabled_when_unsupported():
    """SWA models cannot transplant prefixes: the server drops the store
    rather than corrupting the ring alignment."""
    cfg, params, _ = _setup("h2o-danube-1.8b")
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    rep = serve_dataset(cfg, params, _requests(cfg, [3, 2], shared=9),
                        plan, 4, max_seq=S + DEC, kv_page_tokens=4,
                        prefix_cache=True)
    assert rep.prefix_hits == 0 and rep.prefix_misses == 0
    assert len(rep.request_results) == 2


# ---------------------------------------------------------------------------
# Eviction retrace fix
# ---------------------------------------------------------------------------
def test_evict_rows_padded_width_shares_one_trace():
    """Eviction sets of size 1..8 pad to one width (8): slot recycling must
    not retrace per distinct set size (the bugfix this PR asserts)."""
    cfg, params, toks = _setup()
    eng = ModuleBatchingEngine(cfg, params, Plan(B=8, b_a=4, b_e=8, omega=0.0),
                               max_seq=S)
    eng.prefill(jnp.tile(toks, (2, 1)))
    r0 = evict_retraces()
    for n in range(1, 8):
        eng.cache = evict_rows(eng.cache, list(range(n)))
    assert evict_retraces() - r0 <= 1             # width 8, possibly cached
    eng.cache = evict_rows(eng.cache, list(range(8)))
    assert evict_retraces() - r0 <= 1             # still width 8
    for li in range(cfg.num_layers):
        assert not np.asarray(eng.cache[li]["k"][:8]).any()


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------
def test_serving_package_exports_the_cache_api():
    import repro.serving as S

    for name in ("CacheConfig", "KVPageTable", "PrefixStore"):
        assert hasattr(S, name), name
        assert name in S.__all__, name
