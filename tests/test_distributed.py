"""Distributed serving: expert-parallel mesh engine + replica server.

The mesh decode contract is the hypothesis-style property at the heart of
the subsystem (tested WITHOUT importing hypothesis, which this environment
does not ship): across random ragged workloads, expert-parallel degrees
and both schedulers, serving on a ``(1, ep)`` mesh generates tokens
IDENTICAL to the single-device engine — distribution moves WHERE experts
run, never WHICH tokens come out.  Device count locks at first backend
init, so every mesh case runs in a child interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
``test_multidevice`` pattern); the sanitizer-strict serve rides in the
same subprocess.

The replica server, the engine-construction validation and the pure
helpers run in-process (no mesh required).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["REPRO_SANITIZE"] = "strict"
    import jax
    import numpy as np
    from repro import analysis
    from repro.configs import get_config
    from repro.core.dag_builder import Plan
    from repro.models import model as M
    from repro.serving.server import (
        Request, ServeConfig, Server, StreamConfig,
    )
    from repro.sharding.specs import ShardCtx

    assert len(jax.devices()) == 8, jax.devices()
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plan = Plan(B=8, b_a=8, b_e=64, decode_chunk=4)
    rng = np.random.default_rng(0)

    # 8 requests pad the static wave to the full B=8, so the decode batch
    # divides every ep degree and the collective path (not the T % n
    # single-device fallback) is what each mesh case exercises
    def workload(trial):
        lens = rng.integers(3, 17, size=8)
        reqs = [
            Request(
                prompt=rng.integers(
                    1, cfg.vocab_size, size=int(s)
                ).astype(np.int32),
                decode_len=int(rng.integers(2, 8)),
            )
            for s in lens
        ]
        return reqs

    def serve(reqs, scheduler, sctx=None, ep_chunks=1):
        sv = Server(
            cfg, params, plan,
            ServeConfig(scheduler=scheduler, sctx=sctx,
                        ep_chunks=ep_chunks),
            StreamConfig(),
        )
        for r in reqs:
            sv.submit(r)
        rep = sv.run()
        toks = [rr.tokens.tolist() for rr in rep.request_results]
        return rep, toks

    meshes = {
        ep: ShardCtx(
            mesh=jax.make_mesh((1, ep), ("data", "model")),
            batch_axes=("data",), model_axis="model", moe_dispatch="a2a",
        )
        for ep in (1, 2, 4)
    }
    for trial in range(2):
        reqs = workload(trial)
        for scheduler in ("static", "continuous"):
            _, want = serve(reqs, scheduler)
            for ep, sctx in meshes.items():
                rep, got = serve(reqs, scheduler, sctx=sctx, ep_chunks=2)
                assert got == want, (trial, scheduler, ep, got, want)
                if ep > 1:
                    assert rep.a2a_bytes > 0, (trial, scheduler, ep)
                    assert rep.collective_dispatches > 0

    # sanitizer-strict pass over a mesh Server.run(): decode regions run
    # under jax.transfer_guard('disallow'); the mesh batch/combine moves
    # must all land in planned-transfer scopes
    with analysis.sanitize(strict=True, donation=True) as san:
        rep, got = serve(workload(99), "static", sctx=meshes[4],
                         ep_chunks=4)
    # strict mode raises on any unplanned transfer, so reaching here IS
    # the pass; the planned-transfer ledger must show the mesh scopes
    sr = san.report()
    assert any(k.startswith("ep-a2a") for k in sr["planned_transfers"]), sr
    bad = [d for d in sr["donation_checks"] if not d["ok"]]
    assert not bad, bad
    print("DISTRIBUTED_MESH_OK", rep.a2a_bytes)
    """
)


def _run_child(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-c", script], env=env,
        capture_output=True, text=True, timeout=1500,
    )


def test_mesh_decode_token_identical_property():
    """ep in {1,2,4} x {static,continuous} x random ragged workloads:
    mesh serving is token-for-token the single-device serve, with a
    sanitizer-strict pass over the mesh Server riding along."""
    r = _run_child(MESH_SCRIPT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "DISTRIBUTED_MESH_OK" in r.stdout


# ---------------------------------------------------------------------------
# In-process: replica server + construction validation + pure helpers
# ---------------------------------------------------------------------------
def _smoke_setup():
    import jax

    from repro.configs import get_config
    from repro.core.dag_builder import Plan
    from repro.models import model as M

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plan = Plan(B=8, b_a=8, b_e=64, decode_chunk=4)
    return cfg, params, plan


def _requests(cfg, n=6, seed=0):
    from repro.serving.server import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(3, 12))).astype(
                np.int32),
            decode_len=int(rng.integers(2, 7)),
        )
        for _ in range(n)
    ]


@pytest.mark.parametrize("policy", ["round-robin", "least-loaded"])
def test_replica_server_drains_identically(policy):
    """N replicas behind one queue finish the same tokens as one Server,
    re-indexed to global submission order."""
    from repro.distributed import ReplicaServer
    from repro.serving.server import ServeConfig, Server

    cfg, params, plan = _smoke_setup()
    reqs = _requests(cfg)

    one = Server(cfg, params, plan, ServeConfig(scheduler="static"))
    for r in reqs:
        one.submit(r)
    want = [rr.tokens.tolist() for rr in one.run().request_results]

    rs = ReplicaServer(cfg, params, 2, plan=plan,
                       serve=ServeConfig(scheduler="static"), policy=policy)
    for r in reqs:
        rs.submit(r)
    rep = rs.run()
    got = [rr.tokens.tolist() for rr in rep.merged.request_results]
    assert got == want
    assert [rr.index for rr in rep.merged.request_results] == list(
        range(len(reqs)))
    assert len(rep.per_replica) == 2
    # every request landed on exactly one replica
    assert sum(len(r.request_results) for r in rep.per_replica) == len(reqs)
    # work counters sum, phase times take the parallel max
    assert rep.merged.decode_slot_steps == sum(
        r.decode_slot_steps for r in rep.per_replica)
    assert rep.merged.decode_s == max(r.decode_s for r in rep.per_replica)


def test_replica_server_custom_policy_and_errors():
    from repro.distributed import ReplicaServer
    from repro.serving.server import ServeConfig

    cfg, params, plan = _smoke_setup()
    with pytest.raises(ValueError, match="routing policy"):
        ReplicaServer(cfg, params, 2, plan=plan, policy="zigzag")

    # a callable policy routes every request to replica 1
    rs = ReplicaServer(cfg, params, 2, plan=plan,
                       serve=ServeConfig(scheduler="static"),
                       policy=lambda servers, req: 1)
    for r in _requests(cfg, n=3):
        rs.submit(r)
    rep = rs.run()
    assert len(rep.per_replica[0].request_results) == 0
    assert len(rep.per_replica[1].request_results) == 3


def test_mesh_engine_rejects_unsupported_combos():
    """Clear ValueErrors instead of silent single-device fallbacks."""
    import jax
    from dataclasses import replace

    from repro.core.engine import ModuleBatchingEngine
    from repro.distributed import validate_ep_shard
    from repro.sharding.specs import ShardCtx

    cfg, params, plan = _smoke_setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                    moe_dispatch="a2a")

    # the 1x1 mesh composes fine (and must stay token-compatible)
    ModuleBatchingEngine(cfg, params, plan, sctx=sctx)

    with pytest.raises(ValueError, match="predict_topk"):
        ModuleBatchingEngine(cfg, params,
                             replace(plan, predict_topk=2), sctx=sctx)
    with pytest.raises(ValueError, match="expert_path"):
        ModuleBatchingEngine(cfg, params, plan, sctx=sctx,
                             expert_path="loop")
    with pytest.raises(ValueError, match="moe_dispatch"):
        validate_ep_shard(cfg, replace(sctx, moe_dispatch="grouped"))
    # num_experts % n needs n > 1 to fire — exercised in the mesh
    # subprocess; here check the no-mesh contract instead
    assert validate_ep_shard(cfg, None) == 1


def test_ep_helpers():
    from repro.configs import get_config
    from repro.distributed import a2a_bytes_per_stage, pipeline_chunks

    assert pipeline_chunks(8, 4) == 4
    assert pipeline_chunks(8, 3) == 2      # largest divisor <= requested
    assert pipeline_chunks(7, 4) == 1
    assert pipeline_chunks(8, 100) == 8

    cfg = get_config("mixtral-8x7b", smoke=True)
    assert a2a_bytes_per_stage(cfg, T=8, n_model=1) == 0
    b2 = a2a_bytes_per_stage(cfg, T=8, n_model=2)
    b4 = a2a_bytes_per_stage(cfg, T=8, n_model=4)
    assert b2 > 0 and b4 == 2 * b2         # scales with the rank count
    copies = 8 * cfg.experts_per_token
    assert b2 == copies * 2 * (2 * cfg.d_model * 4 + 4)


def test_planner_mesh_shape_picks_chunks():
    """search_decode(mesh_shape=...) returns an expert-parallel plan whose
    modeled throughput is no worse than serial a2a (chunking only hides
    wire time) and a valid chunk count."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.core.dag_builder import estimate_decode
    from repro.core.hardware import PROFILES
    from repro.core.planner import search_decode

    cfg = get_config("mixtral-8x7b")
    hw = PROFILES["C1-A5000-256GB"]
    res = search_decode(cfg, hw, ctx=256, mesh_shape=(1, 4))
    assert res.plan.ep_chunks in (1, 2, 4, 8)
    serial = estimate_decode(cfg, hw, replace(res.plan, ep_chunks=1),
                             256, mesh_shape=(1, 4))
    assert res.estimate.throughput >= serial.throughput * (1 - 1e-9)
    # the a2a exchange is on the modeled critical path
    est = estimate_decode(cfg, hw, res.plan, 256, mesh_shape=(1, 4))
    assert est.throughput == pytest.approx(res.estimate.throughput)


def test_hardware_a2a_time():
    from repro.core.hardware import PROFILES

    hw = PROFILES["tpu-v5e"]
    assert hw.a2a_time(1e9, 1) == 0.0
    t2, t4 = hw.a2a_time(1e9, 2), hw.a2a_time(1e9, 4)
    assert 0 < t2 < t4                      # more ranks -> more wire
    assert hw.a2a_time(0.0, 4) == 0.0
    # falls back to the host link when no ICI is profiled
    pcie = PROFILES["C1-A5000-256GB"]
    assert pcie.a2a_time(1e9, 2) > pcie.launch_overhead_s
