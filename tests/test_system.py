"""End-to-end behaviour: the full MoE-Gen pipeline on a small real model.

plan search -> engine execution -> identical tokens to the reference system,
plus the property-based invariants of the batching abstraction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.models import model as M
from repro.serving.generate import greedy_generate

KEY = jax.random.PRNGKey(0)


def test_end_to_end_pipeline():
    """Planner (scaled host memory) -> engine -> tokens == reference."""
    from repro.core import planner
    from repro.core.hardware import HardwareProfile
    from repro.core import workload as W

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    # a toy 'testbed' whose host memory admits ~8 sequences of ctx 32
    hw = HardwareProfile(
        name="toy",
        device_flops=1e12, device_mem_bw=1e11, device_mem_bytes=1e9,
        saturation_tokens=64,
        host_mem_bytes=W.model_bytes(cfg) + 8 * W.kv_bytes_per_seq(cfg, 32),
        cpu_flops=1e11, cpu_mem_bw=1e10,
    )
    res = planner.search_decode(cfg, hw, ctx=32)
    assert 1 <= res.plan.B <= 9
    B, S, DEC = min(res.plan.B, 8), 8, 5
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    plan = Plan(B=B, b_a=max(1, min(res.plan.b_a, B)), b_e=4, omega=0.0)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    got = eng.generate(toks, DEC)
    ref = greedy_generate(cfg, params, toks, DEC)
    # greedy trajectories on a random bf16 model can flip near-ties; demand
    # a strong majority of identical tokens
    match = float(jnp.mean((got == ref).astype(jnp.float32)))
    assert match >= 0.7, match


@settings(max_examples=10, deadline=None)
@given(
    b_a=st.integers(1, 8),
    b_e=st.integers(1, 16),
)
def test_engine_invariant_to_microbatching(b_a, b_e):
    """Module-based batching is a pure re-ordering: outputs do not depend on
    (b_a, b_e) choices (up to bf16 noise)."""
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=b_a, b_e=b_e, omega=0.0), max_seq=16
    )
    eng.prefill(toks)
    logits = eng.decode_step(toks[:, 0], 8)
    eng_ref = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=4, b_e=1 << 20, omega=0.0), max_seq=16
    )
    eng_ref.prefill(toks)
    ref = eng_ref.decode_step(toks[:, 0], 8)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    d = float(jnp.max(jnp.abs(logits.astype(jnp.float32) -
                              ref.astype(jnp.float32)))) / scale
    assert d < 0.05, d
