"""End-to-end behaviour: the full MoE-Gen pipeline on a small real model.

plan search -> engine execution -> identical tokens to the reference system.
(The property-based micro-batching invariant lives in test_properties.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.models import model as M
from repro.serving.generate import greedy_generate

KEY = jax.random.PRNGKey(0)


def test_end_to_end_pipeline():
    """Planner (scaled host memory) -> engine -> tokens == reference."""
    from repro.core import planner
    from repro.core.hardware import HardwareProfile
    from repro.core import workload as W

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    # a toy 'testbed' whose host memory admits ~8 sequences of ctx 32
    hw = HardwareProfile(
        name="toy",
        device_flops=1e12, device_mem_bw=1e11, device_mem_bytes=1e9,
        saturation_tokens=64,
        host_mem_bytes=W.model_bytes(cfg) + 8 * W.kv_bytes_per_seq(cfg, 32),
        cpu_flops=1e11, cpu_mem_bw=1e10,
    )
    res = planner.search_decode(cfg, hw, ctx=32)
    assert 1 <= res.plan.B <= 9
    B, S, DEC = min(res.plan.B, 8), 8, 5
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    # b_e = per-expert capacity: B admits every routed token (no drops)
    plan = Plan(B=B, b_a=max(1, min(res.plan.b_a, B)), b_e=B, omega=0.0)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    got = eng.generate(toks, DEC)
    ref = greedy_generate(cfg, params, toks, DEC)
    # greedy trajectories on a random bf16 model can flip near-ties; demand
    # a strong majority of identical tokens
    match = float(jnp.mean((got == ref).astype(jnp.float32)))
    assert match >= 0.7, match
    assert eng.stats.expert_tokens_dropped == 0
