"""Training substrate: loss decreases, checkpoint roundtrip, chunked CE."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.datasets import synthetic_batches
from repro.models import model as M
from repro.models.layers import softmax_cross_entropy
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import adamw_init
from repro.train.train_loop import make_train_step

KEY = jax.random.PRNGKey(0)


def test_loss_decreases():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = M.init_params(cfg, KEY)
    step = jax.jit(make_train_step(cfg, lr=3e-3, remat=False))
    opt = adamw_init(params)
    batches = synthetic_batches(cfg.vocab_size, 4, 32)
    # fixed batch => loss must drop when overfitting it
    tokens, labels = next(batches)
    tokens, labels = jnp.asarray(tokens), jnp.asarray(labels)
    first = None
    for i in range(12):
        params, opt, m = step(params, opt, tokens, labels)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.2, (first, float(m["loss"]))


def test_chunked_loss_matches_full_logits():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, 1)
    loss_chunked, (nll, aux) = M.loss_fn(
        cfg, params, toks, labels, remat=False, aux_weight=0.0, vocab_chunk=8
    )
    logits, _, _ = M.forward(cfg, params, toks)
    nll_full = softmax_cross_entropy(logits, labels)
    assert abs(float(loss_chunked) - float(nll_full)) < 2e-2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, step=17)
    like = jax.tree.map(lambda a: jnp.zeros_like(a), params)
    restored, step = load_checkpoint(path, like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_grad_clip_bounds_update():
    from repro.train.optimizer import adamw_update

    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 1e6)}
    state = adamw_init(params)
    new_params, _, gnorm = adamw_update(params, grads, state, lr=1e-2,
                                        weight_decay=0.0)
    assert float(gnorm) > 1e5
    # clipped: update magnitude ~ lr
    assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) < 0.05
