"""MoE layer: routing, capacity dispatch, expert-parallel shard_map path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.models.moe import (
    _dispatch_combine,
    init_moe_params,
    moe_apply_capacity_local,
    moe_apply_local,
    moe_apply_sharded,
    moe_capacity,
    route,
)
from repro.sharding.specs import ShardCtx

KEY = jax.random.PRNGKey(11)


def _cfg(cf=8.0):
    cfg = get_config("olmoe-1b-7b", smoke=True)
    return replace(cfg, capacity_factor=cf)


def test_routing_topk_normalized():
    cfg = _cfg()
    p = init_moe_params(cfg, KEY)
    x = jax.random.normal(KEY, (32, cfg.d_model))
    gates, idx, probs = route(cfg, p["router"], x)
    assert gates.shape == (32, cfg.experts_per_token)
    assert jnp.allclose(gates.sum(-1), 1.0, atol=1e-5)
    assert int(idx.max()) < cfg.num_experts
    # top-k really is top-k of probs
    ref = jnp.argsort(-probs, axis=-1)[:, : cfg.experts_per_token]
    assert jnp.array_equal(jnp.sort(idx, -1), jnp.sort(ref, -1))


def test_capacity_matches_exact_when_not_dropping():
    cfg = _cfg(cf=64.0)         # capacity >> needed: no token drops
    p = init_moe_params(cfg, KEY)
    x = (jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3).astype(jnp.bfloat16)
    y_exact, _ = moe_apply_local(cfg, p, x)
    y_cap, _ = moe_apply_capacity_local(cfg, p, x)
    diff = jnp.max(jnp.abs(y_exact.astype(jnp.float32) -
                           y_cap.astype(jnp.float32)))
    assert diff < 0.03, diff


def test_sharded_matches_local_on_1dev_mesh():
    cfg = _cfg(cf=64.0)
    p = init_moe_params(cfg, KEY)
    x = (jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3).astype(jnp.bfloat16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
    y_sh, aux_sh = moe_apply_sharded(cfg, p, x, ctx)
    y_loc, aux_loc = moe_apply_local(cfg, p, x)
    diff = jnp.max(jnp.abs(y_sh.astype(jnp.float32) -
                           y_loc.astype(jnp.float32)))
    assert diff < 0.03, diff
    assert abs(float(aux_sh) - float(aux_loc)) < 1e-3


def test_dispatch_conservation():
    """Every kept (token, expert) slot contributes exactly once."""
    cfg = _cfg(cf=64.0)
    T, D = 64, cfg.d_model
    x = jnp.ones((T, D), jnp.float32)
    gates = jnp.full((T, cfg.experts_per_token), 1.0 / cfg.experts_per_token)
    idx = jax.random.randint(
        KEY, (T, cfg.experts_per_token), 0, cfg.num_experts
    )
    # identity experts: w_gate such that silu(g)*u @ wd == x is hard; instead
    # count via an expert that returns constant 1 rows
    wg = jnp.zeros((cfg.num_experts, D, 8)) + 10.0   # silu(10·sum x) ~ large
    wu = jnp.full((cfg.num_experts, D, 8), 1.0 / (8 * D))
    wd = jnp.ones((cfg.num_experts, 8, D))
    cap = moe_capacity(cfg, T)
    y = _dispatch_combine(cfg, x, gates, idx, wg, wu, wd, jnp.int32(0), cap)
    assert y.shape == (T, D)
    assert bool(jnp.isfinite(y).all())


def test_capacity_drops_bound_memory():
    cfg = _cfg(cf=1.0)
    assert moe_capacity(cfg, 1024) <= int(
        1024 * cfg.experts_per_token / cfg.num_experts * 1.0 + 8
    ) + 8


def test_load_balance_loss_uniform_is_one():
    from repro.models.moe import load_balance_loss

    cfg = _cfg()
    T, E = 4096, cfg.num_experts
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.stack(
        [jnp.arange(T) % E, (jnp.arange(T) + 1) % E], axis=1
    )[:, : cfg.experts_per_token]
    lb = load_balance_loss(cfg, probs, idx)
    assert abs(float(lb) - 1.0) < 0.05
