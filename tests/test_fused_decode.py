"""Fused donated decode: one launch per chunk, token-identical to per-module.

The fused macro-step (``engine._fused_decode_chunk``) runs embed -> the
whole layer schema -> head -> per-slot sampling as ONE jitted, donated
device dispatch, scanned over T decode ticks.  These tests pin the
contract: tokens are bit-identical to the per-module path (the oracle,
``fused_decode=False``) across archs, sampling modes, ragged lengths, the
ω host/device split and the loop expert path; the chunk really is one
dispatch; retraces are counted; streamed residency falls back.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import engine as engine_mod
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine, dispatch_count
from repro.models import model as M
from repro.serving.sampling import BatchSampler, SamplingParams

KEY = jax.random.PRNGKey(0)
B, S, DEC = 4, 12, 8


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


def _generate(cfg, params, toks, fused, chunk, plan=None, **kw):
    plan = plan or Plan(B=B, b_a=2, b_e=B, omega=0.0, decode_chunk=chunk)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                               fused_decode=fused)
    out = np.asarray(eng.generate(toks, DEC, **kw))
    return out, eng


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-370m",
                                  "jamba-1.5-large-398b"])
def test_fused_chunk_matches_per_module_greedy(arch):
    """Attn / SSM / hybrid archs: fused multi-token chunks produce the
    exact per-module greedy token streams, in ONE dispatch per chunk."""
    cfg, params, toks = _setup(arch)
    ref, _ = _generate(cfg, params, toks, fused=False, chunk=1)
    got, eng = _generate(cfg, params, toks, fused=True, chunk=4)
    assert np.array_equal(ref, got)
    assert eng.stats.fused_dispatches == 2            # ceil((DEC-1)/4)
    assert eng.stats.fused_ticks == DEC - 1


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-1.5-large-398b"])
def test_fused_chunk_matches_per_module_sampled(arch):
    """Seeded temperature/top-k streams are bit-identical fused vs
    per-module (the shared ``sample_tokens`` + in-carry token indices)."""
    cfg, params, toks = _setup(arch)
    sp = SamplingParams(temperature=0.8, top_k=5, seed=13)
    ref, _ = _generate(cfg, params, toks, fused=False, chunk=1, sampling=sp)
    got, _ = _generate(cfg, params, toks, fused=True, chunk=4, sampling=sp)
    assert np.array_equal(ref, got)


def test_fused_chunk_matches_per_module_ragged():
    """Ragged right-padded batches decode at per-sequence positions inside
    the fused chunk — token-identical to the per-module path."""
    cfg, params, _ = _setup("mixtral-8x7b")
    lens = np.asarray([12, 7, 4])
    rng = np.random.default_rng(0)
    padded = np.zeros((3, 12), np.int32)
    for i, n in enumerate(lens):
        padded[i, :n] = rng.integers(0, cfg.vocab_size, n)
    plan = Plan(B=3, b_a=2, b_e=16, omega=0.0, decode_chunk=4)
    ref = np.asarray(ModuleBatchingEngine(
        cfg, params, plan, max_seq=12 + DEC, fused_decode=False
    ).generate(jnp.asarray(padded), DEC, lengths=lens, chunk=1))
    got = np.asarray(ModuleBatchingEngine(
        cfg, params, plan, max_seq=12 + DEC
    ).generate(jnp.asarray(padded), DEC, lengths=lens))
    assert np.array_equal(ref, got)


def test_fused_keeps_host_rows_outside_launch():
    """ω>0: the host-path attention rows decode per-module OUTSIDE the
    fused launch (host stats advance) and tokens still match the fully
    per-module oracle."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.5, decode_chunk=4)
    ref, ref_eng = _generate(cfg, params, toks, fused=False, chunk=1,
                             plan=plan)
    got, eng = _generate(cfg, params, toks, fused=True, chunk=4, plan=plan)
    assert np.array_equal(ref, got)
    assert eng.stats.fused_dispatches > 0
    n_attn = sum(1 for k, _ in eng.schema if k == "attn")
    # 2 of 4 rows host-path, every decode tick, plus prefill == per-module
    assert eng.stats.host_attn_tokens == ref_eng.stats.host_attn_tokens
    assert eng.stats.host_attn_tokens >= 2 * (DEC - 1) * n_attn


def test_fused_matches_loop_expert_path():
    """The loop expert oracle (never fused) and the fused grouped path
    generate identical tokens when capacity admits every routed token."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.0, decode_chunk=4)
    loop = np.asarray(ModuleBatchingEngine(
        cfg, params, plan, max_seq=S + DEC, expert_path="loop"
    ).generate(toks, DEC))
    fused, eng = _generate(cfg, params, toks, fused=True, chunk=4, plan=plan)
    assert np.array_equal(loop, fused)
    assert eng.stats.fused_dispatches > 0


def test_fused_chunk_is_one_dispatch():
    """Regression: a fused T-token chunk is exactly ONE device dispatch;
    the per-module path costs O(layers * modules) per tick."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=B, b_e=B, omega=0.0, decode_chunk=4)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    cur = jnp.argmax(eng.prefill(toks), -1)
    sampler = BatchSampler.uniform(B, None)
    eng.decode_chunk(cur, jnp.int32(S), sampler, 4)   # compile once
    d0 = dispatch_count()
    eng.decode_chunk(cur, jnp.int32(S), sampler, 4)
    assert dispatch_count() - d0 == 1
    # per-module oracle: > 1 dispatch for a single tick
    ref = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                               fused_decode=False)
    ref.prefill(toks)
    d0 = dispatch_count()
    ref.decode_step(cur, S)
    assert dispatch_count() - d0 > 1


def test_fused_retrace_counter():
    """Repeated same-shape chunks reuse the cached callable (retraces
    stays put); a new (B, path, chunk) key is counted as a retrace."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=B, b_e=B, omega=0.0, decode_chunk=4)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    cur = jnp.argmax(eng.prefill(toks), -1)
    sampler = BatchSampler.uniform(B, None)
    eng.decode_chunk(cur, jnp.int32(S), sampler, 4)
    eng.decode_chunk(cur, jnp.int32(S), sampler, 4)
    assert eng.stats.decode_retraces == 1
    eng.decode_chunk(cur, jnp.int32(S), sampler, 2)   # new chunk length
    assert eng.stats.decode_retraces == 2


def test_streamed_residency_falls_back_to_per_module():
    """Streamed weights keep the per-layer dispatch loop (the prefetch
    needs the layer boundary) — no fused dispatch is issued, tokens still
    identical to the fused resident run."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.0, decode_chunk=4)
    fused, _ = _generate(cfg, params, toks, fused=True, chunk=4, plan=plan)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                               stream_weights=True, resident_bytes=0.0)
    assert not eng.fused_eligible()
    got = np.asarray(eng.generate(toks, DEC))
    assert eng.stats.fused_dispatches == 0
    assert eng.stats.weight_htod_bytes > 0
    assert np.array_equal(fused, got)


def test_decode_step_sampled_takes_fused_path():
    """The single-tick sampled entry point rides the fused launch when
    eligible and matches the per-module tick exactly."""
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=B, b_e=B, omega=0.0, decode_chunk=4)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    ref = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                               fused_decode=False)
    cur = jnp.argmax(eng.prefill(toks), -1)
    ref.prefill(toks)
    t_f = np.asarray(eng.decode_step_sampled(
        cur, jnp.int32(S), BatchSampler.uniform(B, None)))
    t_r = np.asarray(ref.decode_step_sampled(
        cur, jnp.int32(S), BatchSampler.uniform(B, None)))
    assert np.array_equal(t_f, t_r)
    assert eng.stats.fused_dispatches == 1


def test_select_decode_chunk_cadence():
    """Planner T: static waves chunk up to the wave length; continuous
    chunks below the eviction cadence mean_decode_len / B; an arrival
    stream tightens it further; always a power of two in [1, cap]."""
    from repro.core.planner import select_decode_chunk

    p_small = Plan(B=4, b_a=4, b_e=8, omega=0.0)
    p_big = Plan(B=512, b_a=32, b_e=8, omega=0.0)
    assert select_decode_chunk(p_small, 64, scheduler="static") == 64
    assert select_decode_chunk(p_small, 64) == 16         # 64/4 ticks/evict
    assert select_decode_chunk(p_big, 64) == 1            # evicts every tick
    assert select_decode_chunk(p_small, 64, arrival_rate=10.0,
                               step_time_s=0.05) == 2     # 2 ticks/arrival
    assert select_decode_chunk(p_small, 10 ** 9, scheduler="static") == 64
