"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_scan as ssd_jnp

KEY = jax.random.PRNGKey(5)


def _rand(shape, dtype, scale=1.0, key=KEY):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 0.05}


# ---------------------------------------------------------------------------
# Grouped expert GEMM + fused FFN
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 128, 256, 128), (4, 256, 512, 384),
                                     (1, 384, 256, 256)])
def test_grouped_matmul(E, C, D, F, dtype):
    x = _rand((E, C, D), dtype, 0.3)
    w = _rand((E, D, F), dtype, 0.05)
    got = ops.grouped_matmul(x, w, interpret=True)
    want = ref.grouped_matmul_ref(x, w)
    d = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert d < TOL[dtype] * D ** 0.5, d


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 128, 256, 128), (3, 256, 128, 384)])
def test_expert_ffn_fused(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 4)
    x = _rand((E, C, D), dtype, 0.3, ks[0])
    wg = _rand((E, D, F), dtype, 0.05, ks[1])
    wu = _rand((E, D, F), dtype, 0.05, ks[2])
    wd = _rand((E, F, D), dtype, 0.05, ks[3])
    got = ops.expert_ffn(x, wg, wu, wd, interpret=True)
    want = ref.expert_ffn_ref(x, wg, wu, wd)
    d = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert d < TOL[dtype], d


def test_expert_ffn_padding_path():
    """C not a tile multiple exercises the ops.py padding."""
    ks = jax.random.split(KEY, 4)
    x = _rand((2, 100, 256), jnp.float32, 0.3, ks[0])
    wg = _rand((2, 256, 128), jnp.float32, 0.05, ks[1])
    wu = _rand((2, 256, 128), jnp.float32, 0.05, ks[2])
    wd = _rand((2, 128, 256), jnp.float32, 0.05, ks[3])
    got = ops.expert_ffn(x, wg, wu, wd, interpret=True)
    want = ref.expert_ffn_ref(x, wg, wu, wd)
    assert jnp.max(jnp.abs(got - want)) < 1e-4


# ---------------------------------------------------------------------------
# Flash decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,S,pos", [
    (2, 8, 2, 64, 512, 300),
    (1, 4, 4, 128, 256, 255),
    (3, 16, 2, 64, 1024, 17),
])
def test_decode_attention(B, H, K, hd, S, pos, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((B, H, hd), dtype, 1.0, ks[0])
    k = _rand((B, S, K, hd), dtype, 1.0, ks[1])
    v = _rand((B, S, K, hd), dtype, 1.0, ks[2])
    got = ops.decode_attention(q, k, v, jnp.int32(pos), interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    d = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert d < TOL[dtype], d


def test_decode_attention_mask_boundary():
    """Slots beyond pos must not contribute: poisoning them changes nothing."""
    ks = jax.random.split(KEY, 3)
    B, H, K, hd, S, pos = 1, 4, 2, 64, 512, 100
    q = _rand((B, H, hd), jnp.float32, 1.0, ks[0])
    k = _rand((B, S, K, hd), jnp.float32, 1.0, ks[1])
    v = _rand((B, S, K, hd), jnp.float32, 1.0, ks[2])
    base = ops.decode_attention(q, k, v, jnp.int32(pos), interpret=True)
    k2 = k.at[:, pos + 1 :].set(1e4)
    v2 = v.at[:, pos + 1 :].set(-1e4)
    poisoned = ops.decode_attention(q, k2, v2, jnp.int32(pos), interpret=True)
    assert jnp.max(jnp.abs(base - poisoned)) < 1e-5


# ---------------------------------------------------------------------------
# Flash attention (prefill/train)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,K", [(512, 4, 2), (1024, 2, 2)])
def test_flash_attention(S, H, K, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((2, S, H, 64), dtype, 1.0, ks[0])
    k = _rand((2, S, K, 64), dtype, 1.0, ks[1])
    v = _rand((2, S, K, 64), dtype, 1.0, ks[2])
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, H // K, 2),
                                   jnp.repeat(v, H // K, 2))
    d = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    assert d < TOL[dtype], d


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,nh,hp,ns,chunk", [
    (256, 4, 32, 16, 64),
    (128, 8, 16, 32, 32),
])
def test_ssd_scan_kernel(S, nh, hp, ns, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = _rand((2, S, nh, hp), dtype, 0.5, ks[0])
    B_in = _rand((2, S, ns), dtype, 0.5, ks[1])
    C_in = _rand((2, S, ns), dtype, 0.5, ks[2])
    dt = jax.nn.softplus(jax.random.normal(ks[3], (2, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[4], (nh,)) * 0.3)
    y, h = ops.ssd_scan(x, B_in, C_in, dt, A, chunk, interpret=True)
    y_ref, h_ref = ssd_jnp(x, B_in, C_in, dt, A, chunk)
    dy = jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32)))
    dh = jnp.max(jnp.abs(h - h_ref))
    assert dy < TOL[dtype] * 4, dy
    assert dh < 1e-2 if dtype == jnp.float32 else dh < 0.5
