"""Batching planner + DAG cost model: constraints and paper-claim directions.

(Property-based variants live in test_properties.py, the only module allowed
to import hypothesis.)
"""
import pytest

from repro.configs import get_config
from repro.core import baselines, planner, workload as W
from repro.core.dag import JobDag
from repro.core.dag_builder import Plan, estimate_decode, estimate_prefill
from repro.core.hardware import A5000_C2, A6000_C3

CTX = 768


def test_host_memory_limit_eq2():
    cfg = get_config("mixtral-8x7b")
    B_max = planner.host_batch_limit(cfg, A5000_C2, CTX)
    used = B_max * W.kv_bytes_per_seq(cfg, CTX) + W.model_bytes(cfg)
    assert used <= A5000_C2.host_mem_bytes
    # one more sequence would overflow
    over = (B_max + 2) * W.kv_bytes_per_seq(cfg, CTX) + W.model_bytes(cfg)
    assert over > A5000_C2.host_mem_bytes


def test_device_memory_constraint_eq3():
    cfg = get_config("mixtral-8x7b")
    res = planner.search_decode(cfg, A5000_C2, CTX)
    assert planner.device_memory_ok(cfg, A5000_C2, res.plan, CTX, "decode")


def test_module_batching_beats_model_based_decode():
    """The paper's headline: 8-31x decode throughput over model-based."""
    cfg = get_config("mixtral-8x7b")
    ours = planner.search_decode(cfg, A5000_C2, CTX).estimate.throughput
    for system in ("deepspeed", "flexgen", "moe-lightning", "vllm"):
        base = baselines.estimate_baseline_decode(
            cfg, A5000_C2, CTX, system
        ).throughput
        assert ours > 3 * base, (system, ours, base)
    ds = baselines.estimate_baseline_decode(cfg, A5000_C2, CTX, "deepspeed")
    assert ours / ds.throughput > 5     # paper Table 6: 17x for Mixtral-8x22B-class


def test_prefill_gain_grows_with_sparsity():
    """Paper Table 7: gains are larger for sparser MoE (olmoe 64e-top8 vs
    mixtral 8e-top2)."""
    gain = {}
    for arch in ("mixtral-8x7b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        ours = planner.search_prefill(cfg, A5000_C2, 512).estimate.throughput
        base = baselines.estimate_baseline_prefill(
            cfg, A5000_C2, 512, "deepspeed"
        ).throughput
        gain[arch] = ours / base
    assert gain["olmoe-1b-7b"] >= gain["mixtral-8x7b"]


def test_weak_cpu_lowers_omega():
    """Paper Table 10: C3's weak host drives the split toward the GPU."""
    cfg = get_config("mixtral-8x7b")
    w_c2 = planner.search_decode(cfg, A5000_C2, CTX).plan.omega
    w_c3 = planner.search_decode(cfg, A6000_C3, CTX).plan.omega
    assert w_c3 <= w_c2


def test_decode_B_set_to_host_max():
    cfg = get_config("mixtral-8x7b")
    res = planner.search_decode(cfg, A5000_C2, CTX)
    assert res.plan.B == planner.host_batch_limit(cfg, A5000_C2, CTX)


def test_full_kv_offload_reduces_fetch_traffic():
    """Paper Fig. 4: offloading KV enables batches that amortize weights."""
    cfg = get_config("mixtral-8x7b")
    ours = planner.search_decode(cfg, A5000_C2, CTX)
    base = baselines.estimate_baseline_decode(cfg, A5000_C2, CTX, "deepspeed")
    ours_per_tok = ours.estimate.htod_bytes / ours.estimate.tokens
    base_per_tok = base.htod_bytes / base.tokens
    assert ours_per_tok < base_per_tok / 4


# ---------------------------------------------------------------------------
# DAG properties
# ---------------------------------------------------------------------------
def test_dag_critical_path_simple():
    dag = JobDag()
    a = dag.add("copy", "htod", 2.0)
    b = dag.add("compute", "gpu", 1.0, deps=[a])
    dag.add("copy2", "htod", 0.5)          # overlaps with compute
    assert dag.earliest_finish() == pytest.approx(3.0)
    assert dag.critical_path()[-1] == "compute"


def test_dag_channel_serialization():
    dag = JobDag()
    dag.add("c1", "htod", 1.0)
    dag.add("c2", "htod", 1.0)             # same channel: serializes
    assert dag.earliest_finish() == pytest.approx(2.0)


def test_decode_capacity_never_below_balanced_load():
    """b_e is a per-expert capacity: the search never under-provisions it
    below the balanced per-expert token load (drops would be invisible to
    the throughput objective)."""
    cfg = get_config("mixtral-8x7b")
    res = planner.search_decode(cfg, A5000_C2, CTX)
    per_e = -(-res.plan.B * cfg.experts_per_token // cfg.num_experts)
    assert res.plan.b_e >= per_e
    assert res.plan.b_e <= res.plan.B


def test_expert_buffer_term_in_eq3():
    """The grouped (E, C, D) dispatch buffer is charged against Eq. 3:
    larger capacities consume strictly more device memory."""
    cfg = get_config("mixtral-8x7b")
    lo = Plan(B=4096, b_a=32, b_e=512, omega=0.0)
    hi = Plan(B=4096, b_a=32, b_e=4096, omega=0.0)
    used_lo = planner.device_memory_used(cfg, lo, CTX, "decode")
    used_hi = planner.device_memory_used(cfg, hi, CTX, "decode")
    assert used_hi - used_lo == pytest.approx(
        W.expert_buffer_bytes(cfg, 4096) - W.expert_buffer_bytes(cfg, 512)
    )
