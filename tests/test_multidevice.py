"""Multi-device semantics on 8 fake CPU devices (subprocess: device count
locks at backend init, so these run in a child interpreter).

Checks that the expert-parallel shard_map MoE — both the E >= n_model
partitioned case and the E < n_model replica-split case — matches the
exact local reference, and that a sharded forward matches unsharded.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.moe import (
        init_moe_params, moe_apply_a2a, moe_apply_local, moe_apply_sharded,
    )
    from repro.models import model as M
    from repro.sharding.specs import ShardCtx

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
    key = jax.random.PRNGKey(0)

    # Case 1: E=8 experts over 4 model ranks (2 experts/rank)
    cfg = replace(get_config("olmoe-1b-7b", smoke=True),
                  num_experts=8, experts_per_token=2, capacity_factor=32.0)
    p = init_moe_params(cfg, key)
    x = (jax.random.normal(key, (4, 16, cfg.d_model)) * 0.3).astype(jnp.bfloat16)
    y_loc, _ = moe_apply_local(cfg, p, x)
    y_sh, _ = moe_apply_sharded(cfg, p, x, ctx, small_batch_threshold=0)
    d1 = float(jnp.max(jnp.abs(y_loc.astype(jnp.float32) - y_sh.astype(jnp.float32))))
    assert d1 < 0.05, ("partitioned", d1)

    # Case 2: E=2 experts over 4 model ranks (replica split, n_rep=2)
    cfg2 = replace(cfg, num_experts=2, experts_per_token=1)
    p2 = init_moe_params(cfg2, key)
    y_loc2, _ = moe_apply_local(cfg2, p2, x)
    y_sh2, _ = moe_apply_sharded(cfg2, p2, x, ctx, small_batch_threshold=0)
    d2 = float(jnp.max(jnp.abs(y_loc2.astype(jnp.float32) - y_sh2.astype(jnp.float32))))
    assert d2 < 0.05, ("replica-split", d2)

    # Case 2b: all-to-all dispatch == local (E=8 over 4 ranks, tokens
    # sharded over the model axis as well)
    y_a2a, _ = moe_apply_a2a(cfg, p, x, ctx)
    d2b = float(jnp.max(jnp.abs(y_loc.astype(jnp.float32) - y_a2a.astype(jnp.float32))))
    assert d2b < 0.05, ("a2a", d2b)

    # Case 3: whole-model forward sharded == unsharded (capacity high enough
    # that the GShard-style dispatch drops no tokens)
    cfgm = replace(get_config("mixtral-8x7b", smoke=True), capacity_factor=32.0)
    pm = M.init_params(cfgm, key)
    toks = jax.random.randint(key, (4, 16), 0, cfgm.vocab_size)
    a, _, _ = M.forward(cfgm, pm, toks)
    b, _, _ = M.forward(cfgm, pm, toks, ctx=ctx)
    d3 = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert d3 < 0.08, ("forward", d3)
    print("MULTIDEVICE_OK", d1, d2, d3)
    """
)


@pytest.mark.slow
def test_sharded_moe_on_8_fake_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "MULTIDEVICE_OK" in r.stdout
