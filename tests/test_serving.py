"""Serving layer: generation, KV ring conversion, scheduler, sampling.

(The tokenizer round-trip property test lives in test_properties.py, the
only module allowed to import hypothesis.)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill

KEY = jax.random.PRNGKey(0)


def test_greedy_generate_shape_and_determinism():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (3, 12), 0, cfg.vocab_size)
    a = greedy_generate(cfg, params, toks, 5)
    b = greedy_generate(cfg, params, toks, 5)
    assert a.shape == (3, 5)
    assert jnp.array_equal(a, b)


def test_ring_conversion_places_positions_mod_window():
    cfg = get_config("h2o-danube-1.8b", smoke=True)  # window = 64
    W = cfg.sliding_window
    S = W + 10                                        # prompt longer than window
    G, B, K, hd = 1, 1, cfg.num_kv_heads, cfg.head_dim
    # fabricate a prefill cache where k[pos] = pos
    k = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.float32)[None, None, :, None, None],
        (G, B, S, K, hd),
    ).astype(jnp.bfloat16)
    caches = [{"k": k, "v": k}]
    out = cache_from_prefill(cfg, caches, S, max_seq=S + 8)
    ring = out[0]["k"]                                # (G, B, W, K, hd)
    assert ring.shape[2] == W
    for pos in range(S - W, S):
        slot = pos % W
        assert float(ring[0, 0, slot, 0, 0]) == float(pos)


def test_scheduler_serve_dataset():
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    spec = DatasetSpec("tiny", 6, 8, 4)
    reqs = synthetic_requests(spec, cfg.vocab_size)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    report = serve_dataset(cfg, params, reqs, plan, decode_len=4)
    assert len(report.results) == 2                   # 6 requests / B=4
    assert report.decode_tokens == 6 * 4
    assert report.decode_throughput > 0


def test_sampling_strategies():
    from repro.serving.sampling import greedy, temperature_sample, top_k_sample

    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, 0.1]])
    assert greedy(logits).tolist() == [1, 0]
    k = jax.random.PRNGKey(0)
    t = temperature_sample(k, logits, temperature=1e-4)
    assert t.tolist() == [1, 0]
    tk = top_k_sample(k, logits, k=1)
    assert tk.tolist() == [1, 0]


def test_scheduler_expert_path_choice():
    """serve_dataset surfaces the grouped-vs-loop engine choice and both
    paths serve identical tokens."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("tiny", 4, 8, 4), cfg.vocab_size)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    rep_g = serve_dataset(cfg, params, reqs, plan, 4, expert_path="grouped")
    rep_l = serve_dataset(cfg, params, reqs, plan, 4, expert_path="loop")
    assert np.array_equal(rep_g.results[0].tokens, rep_l.results[0].tokens)
