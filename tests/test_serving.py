"""Serving layer: generation, KV ring conversion, scheduler, sampling.

(The tokenizer round-trip property test lives in test_properties.py, the
only module allowed to import hypothesis.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill

KEY = jax.random.PRNGKey(0)


def test_greedy_generate_shape_and_determinism():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (3, 12), 0, cfg.vocab_size)
    a = greedy_generate(cfg, params, toks, 5)
    b = greedy_generate(cfg, params, toks, 5)
    assert a.shape == (3, 5)
    assert jnp.array_equal(a, b)


def test_ring_conversion_places_positions_mod_window():
    cfg = get_config("h2o-danube-1.8b", smoke=True)  # window = 64
    W = cfg.sliding_window
    S = W + 10                                        # prompt longer than window
    G, B, K, hd = 1, 1, cfg.num_kv_heads, cfg.head_dim
    # fabricate a prefill cache where k[pos] = pos
    k = jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.float32)[None, None, :, None, None],
        (G, B, S, K, hd),
    ).astype(jnp.bfloat16)
    caches = [{"k": k, "v": k}]
    out = cache_from_prefill(cfg, caches, S, max_seq=S + 8)
    ring = out[0]["k"]                                # (G, B, W, K, hd)
    assert ring.shape[2] == W
    for pos in range(S - W, S):
        slot = pos % W
        assert float(ring[0, 0, slot, 0, 0]) == float(pos)


def test_scheduler_serve_dataset():
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    spec = DatasetSpec("tiny", 6, 8, 4)
    reqs = synthetic_requests(spec, cfg.vocab_size)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    report = serve_dataset(cfg, params, reqs, plan, decode_len=4)
    assert len(report.results) == 2                   # 6 requests / B=4
    assert report.decode_tokens == 6 * 4
    assert report.decode_throughput > 0


def test_sampling_strategies():
    from repro.serving.sampling import greedy, temperature_sample, top_k_sample

    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, 0.1]])
    assert greedy(logits).tolist() == [1, 0]
    k = jax.random.PRNGKey(0)
    t = temperature_sample(k, logits, temperature=1e-4)
    assert t.tolist() == [1, 0]
    tk = top_k_sample(k, logits, k=1)
    assert tk.tolist() == [1, 0]


def test_pad_requests_truncates_and_reports_lengths():
    from repro.serving.scheduler import Request, pad_requests

    reqs = [Request(np.arange(12, dtype=np.int32), 4),
            Request(np.arange(5, dtype=np.int32), 4)]
    toks, lens = pad_requests(reqs, pad_id=9, max_prompt_len=8)
    assert toks.shape == (2, 8)                   # truncation is real now
    assert lens.tolist() == [8, 5]
    assert toks[1, 5:].tolist() == [9, 9, 9]      # right-padded with pad_id
    assert toks[0].tolist() == list(range(8))


def test_serve_dataset_rejects_oversized_prompt():
    from repro.core.dag_builder import Plan
    from repro.serving.scheduler import Request, serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = [Request(np.zeros(30, np.int32), 4)]
    with np.testing.assert_raises_regex(ValueError, "max_seq"):
        serve_dataset(cfg, params, reqs, Plan(B=1, b_a=1, b_e=4, omega=0.0),
                      4, max_seq=16)
    # truncation makes the same request servable
    rep = serve_dataset(cfg, params, reqs, Plan(B=1, b_a=1, b_e=4, omega=0.0),
                        4, max_seq=16, max_prompt_len=12)
    assert rep.request_results[0].tokens.size == 4


def test_serve_dataset_ragged_prompts_match_per_sequence():
    """Mixed prompt lengths in one static batch serve the same tokens as
    each request served alone (the seed's ragged-prompt bug: logits taken
    at a pad position for every shorter prompt)."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("rag", 3, 12, 4), cfg.vocab_size,
                              prompt_lens=[12, 7, 9])
    plan = Plan(B=3, b_a=2, b_e=8, omega=0.0)
    rep = serve_dataset(cfg, params, reqs, plan, 4)
    for i, r in enumerate(reqs):
        solo = serve_dataset(cfg, params, [r],
                             Plan(B=1, b_a=1, b_e=8, omega=0.0), 4)
        assert np.array_equal(rep.request_results[i].tokens,
                              solo.request_results[0].tokens), i


def test_serve_dataset_honors_per_request_decode_len():
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("mix", 4, 8, 4), cfg.vocab_size,
                              decode_lens=[2, 6])
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    rep = serve_dataset(cfg, params, reqs, plan, 4)
    assert [r.tokens.size for r in rep.request_results] == [2, 6, 2, 6]
    assert rep.decode_tokens == 16                # not 4 * max(decode_len)
    assert rep.wasted_slot_steps == 4 * 5 - (1 + 5 + 1 + 5)


def test_continuous_scheduler_equivalent_and_fewer_slot_steps():
    """Continuous in-flight batching: identical tokens per request, strictly
    fewer decode-step.slot units than the static scheduler on a
    mixed-decode_len workload."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("mix", 7, 12, 4), cfg.vocab_size,
                              prompt_lens=[12, 7, 9], decode_lens=[3, 8, 5])
    plan = Plan(B=3, b_a=2, b_e=16, omega=0.0)
    rs = serve_dataset(cfg, params, reqs, plan, 4, scheduler="static")
    rc = serve_dataset(cfg, params, reqs, plan, 4, scheduler="continuous")
    assert rc.scheduler == "continuous"
    assert len(rc.request_results) == len(reqs)
    for a, b in zip(rs.request_results, rc.request_results):
        assert a.index == b.index
        assert np.array_equal(a.tokens, b.tokens), a.index
    assert rc.decode_slot_steps < rs.decode_slot_steps
    assert rc.wasted_slot_steps < rs.wasted_slot_steps
    assert rc.occupancy > rs.occupancy
    assert rc.decode_tokens == rs.decode_tokens == sum(
        r.decode_len for r in reqs
    )
    assert all(r.latency_s >= 0 for r in rc.request_results)


def test_continuous_scheduler_eos_frees_slots_early():
    """EOS finishes a sequence before its decode_len; both schedulers trim
    the stream at EOS and the freed slot is recycled."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("eos", 4, 8, 6), cfg.vocab_size)
    plan = Plan(B=2, b_a=2, b_e=8, omega=0.0)
    base = serve_dataset(cfg, params, reqs, plan, 6, scheduler="continuous")
    # pick an eos that actually occurs mid-stream for at least one request
    eos = next(
        int(t) for r in base.request_results for t in r.tokens[:-1]
    )
    rep = serve_dataset(cfg, params, reqs, plan, 6, scheduler="continuous",
                        eos_id=eos)
    assert any(r.tokens.size < 6 for r in rep.request_results)
    for r in rep.request_results:
        if r.tokens.size < 6:
            assert r.tokens[-1] == eos
            assert eos not in r.tokens[:-1]
    assert rep.decode_slot_steps <= base.decode_slot_steps


@pytest.mark.slow
def test_continuous_scheduler_mixed_8_32_128():
    """Acceptance-scale workload: decode lengths drawn from {8, 32, 128} —
    continuous executes strictly fewer decode-step.slot units than static
    with identical tokens per request."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("mix", 6, 16, 32), cfg.vocab_size,
                              decode_lens=[8, 32, 128])
    plan = Plan(B=3, b_a=3, b_e=16, omega=0.0)
    rs = serve_dataset(cfg, params, reqs, plan, 32, scheduler="static")
    rc = serve_dataset(cfg, params, reqs, plan, 32, scheduler="continuous")
    for a, b in zip(rs.request_results, rc.request_results):
        assert a.index == b.index
        assert np.array_equal(a.tokens, b.tokens), a.index
    assert rc.decode_slot_steps < rs.decode_slot_steps
    assert rc.decode_tokens == rs.decode_tokens == 2 * (8 + 32 + 128)


def test_serving_kvcache_slot_insert_evict():
    """scatter_prefill_rows overwrites exactly the target rows; evict_rows
    zeroes them."""
    from repro.serving.kvcache import evict_rows, scatter_prefill_rows

    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = M.init_params(cfg, KEY)
    from repro.core.dag_builder import Plan
    from repro.core.engine import ModuleBatchingEngine

    eng = ModuleBatchingEngine(cfg, params,
                               Plan(B=4, b_a=4, b_e=8, omega=0.0), max_seq=16)
    toks = jax.random.randint(KEY, (4, 8), 0, cfg.vocab_size)
    eng.prefill(toks)
    before = [jax.tree.map(lambda a: np.asarray(a), layer)
              for layer in eng.cache]
    newcomer = jax.random.randint(jax.random.PRNGKey(5), (1, 6),
                                  0, cfg.vocab_size)
    eng.prefill_slots(newcomer, [2], lengths=np.asarray([6]))
    for layer_b, layer_a in zip(before, eng.cache):
        for key in layer_b:
            a, b = np.asarray(layer_a[key]), layer_b[key]
            assert np.array_equal(a[[0, 1, 3]], b[[0, 1, 3]]), key  # untouched
            assert not np.array_equal(a[2], b[2]), key              # replaced
    eng.cache = evict_rows(eng.cache, [2])
    for layer in eng.cache:
        for key in layer:
            assert not np.asarray(layer[key])[2].any(), key


def test_continuous_admission_gated_by_host_kv_budget():
    """Eq. 2 memory admission: with a host budget that fits only two
    in-flight sequences, the continuous scheduler defers the queue head
    until eviction frees KV bytes — same tokens, non-zero deferrals — and
    a request that can NEVER fit raises instead of deadlocking."""
    from dataclasses import replace as dc_replace

    from repro.core import workload as W
    from repro.core.dag_builder import Plan
    from repro.core.hardware import A5000_C2
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("mem", 5, 8, 4), cfg.vocab_size)
    plan = Plan(B=3, b_a=2, b_e=8, omega=0.0)
    need = W.kv_bytes_per_seq(cfg, 8 + 4)
    hw = dc_replace(A5000_C2,
                    host_mem_bytes=W.model_bytes(cfg) + 2.5 * need)
    free_run = serve_dataset(cfg, params, reqs, plan, 4,
                             scheduler="continuous")
    gated = serve_dataset(cfg, params, reqs, plan, 4, scheduler="continuous",
                          hw=hw)
    assert gated.admission_deferrals > 0
    assert free_run.admission_deferrals == 0
    assert len(gated.request_results) == len(reqs)
    for a, b in zip(free_run.request_results, gated.request_results):
        assert a.index == b.index
        assert np.array_equal(a.tokens, b.tokens), a.index
    # a request whose KV can never fit must raise, not wait forever
    hw_tiny = dc_replace(A5000_C2,
                         host_mem_bytes=W.model_bytes(cfg) + 0.5 * need)
    with pytest.raises(ValueError, match="Eq. 2"):
        serve_dataset(cfg, params, reqs, plan, 4, scheduler="continuous",
                      hw=hw_tiny)


def test_scheduler_expert_path_choice():
    """serve_dataset surfaces the grouped-vs-loop engine choice and both
    paths serve identical tokens."""
    from repro.core.dag_builder import Plan
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("tiny", 4, 8, 4), cfg.vocab_size)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    rep_g = serve_dataset(cfg, params, reqs, plan, 4, expert_path="grouped")
    rep_l = serve_dataset(cfg, params, reqs, plan, 4, expert_path="loop")
    assert np.array_equal(rep_g.results[0].tokens, rep_l.results[0].tokens)
