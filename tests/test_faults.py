"""Fault injection + recovery (`repro.faults`): deterministic plans,
retrying stream transfers, watchdog timeouts, memory-pressure degradation,
request preempt/checkpoint/resume, and replica failover.

Every recovery path must be TOKEN-IDENTICAL to the fault-free run (the
ROADMAP recovery-semantics contract) and counted in ``ServeReport``.
Baseline (fault-free) runs execute under ``faults.shielded()`` so the
chaos CI job's ambient ``REPRO_FAULTS`` plan cannot perturb them.

(The randomized chaos property over fault schedules lives in
test_properties.py, the only module allowed to import hypothesis.)
"""
import os

import jax
import numpy as np
import pytest

from repro import analysis, faults
from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.models import model as M
from repro.serving.scheduler import serve_dataset
from repro.serving.server import Request, ServeConfig, Server
from repro.serving.weights import StreamWindow

KEY = jax.random.PRNGKey(0)


def _mixtral():
    cfg = get_config("mixtral-8x7b", smoke=True)
    return cfg, M.init_params(cfg, KEY)


def _prompts(cfg, n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, cfg.vocab_size, length)))
            for _ in range(n)]


def _tokens(report):
    return [list(map(int, r.tokens)) for r in report.request_results]


# ---------------------------------------------------------------------------
# FaultPlan: determinism, spec grammar, progress bound
# ---------------------------------------------------------------------------
def test_fault_spec_parse_roundtrip():
    spec = faults.parse_spec(
        "seed=3,transfer=0.2,stall=0.05,oom=0.1,preempt=7,kill=1@4")
    assert spec.seed == 3
    assert spec.transfer_rate == pytest.approx(0.2)
    assert spec.stall_rate == pytest.approx(0.05)
    assert spec.oom_rate == pytest.approx(0.1)
    assert spec.preempt_every == 7
    assert (spec.kill_replica, spec.kill_after) == (1, 4)
    with pytest.raises(ValueError):
        faults.parse_spec("seed=3,bogus=1")
    with pytest.raises(ValueError):
        faults.parse_spec("preempt")         # key with no value
    bare = faults.parse_spec("kill=1")       # bare kill: fleet step 1
    assert (bare.kill_replica, bare.kill_after) == (1, 1)


def test_fault_plan_draws_are_deterministic():
    """Same spec => identical injection schedule, replayable forever; a
    different seed reshuffles it.  Draws never consult wall-clock or
    Python's salted hash."""
    mk = lambda s: faults.FaultPlan(faults.parse_spec(s))
    a = mk("seed=11,transfer=0.5")
    b = mk("seed=11,transfer=0.5")
    seq_a = [a.transfer_fault("w", k % 3) for k in range(64)]
    seq_b = [b.transfer_fault("w", k % 3) for k in range(64)]
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)
    c = mk("seed=12,transfer=0.5")
    assert [c.transfer_fault("w", k % 3) for k in range(64)] != seq_a


def test_fault_plan_never_fails_twice_consecutively():
    """The progress bound: even at rate 1.0 a site never fails twice in a
    row, so ANY retry policy with max_retries >= 1 always completes."""
    fp = faults.FaultPlan(faults.parse_spec("seed=0,transfer=1.0,oom=1.0"))
    draws = [fp.transfer_fault("stream-window", 5) for _ in range(20)]
    assert draws == [True, False] * 10
    ooms = [fp.page_oom() for _ in range(10)]
    assert not any(a and b for a, b in zip(ooms, ooms[1:]))


def test_fault_resolve_coercions():
    assert faults.resolve(None) is None
    fp = faults.resolve("seed=1,transfer=0.1")
    assert isinstance(fp, faults.FaultPlan)
    assert faults.resolve(fp) is fp
    assert faults.resolve(fp.spec).spec == fp.spec


def test_fault_plan_event_ledger_and_report():
    fp = faults.resolve("seed=0,transfer=1.0")
    with faults.armed(fp):
        faults.note("recovered:test-event")
        faults.note("recovered:test-event", 2)
    rep = fp.report()
    assert rep["spec"]["transfer_rate"] == 1.0
    assert rep["events"]["recovered:test-event"] == 3


def test_shielded_masks_the_armed_plan():
    fp = faults.resolve("seed=0,transfer=1.0")
    with faults.armed(fp):
        assert faults.current() is fp
        with faults.shielded():
            assert faults.current() is None
        assert faults.current() is fp


# ---------------------------------------------------------------------------
# StreamWindow: retry, stall recovery, watchdog timeout (satellite a)
# ---------------------------------------------------------------------------
def _counting_fetch():
    calls = []

    def fetch(key):
        calls.append(key)
        return np.full((4,), float(key)), 32

    return fetch, calls


def test_stream_window_retries_transient_faults():
    """At transfer rate 1.0 every first attempt fails; the never-twice
    bound makes the first retry succeed — acquire returns the value and
    counts the retry."""
    fetch, calls = _counting_fetch()
    win = StreamWindow(fetch, tag="stream-window")
    with faults.armed(faults.resolve("seed=0,transfer=1.0")):
        out = win.acquire(7)
    assert np.array_equal(out, np.full((4,), 7.0))
    assert win.retries >= 1
    assert len(calls) == 1          # the injected failure never reached fetch


def test_stream_window_retry_exhaustion_raises_transient():
    """With retries disabled the injected failure surfaces as the typed
    ``TransientTransferError`` (a ``FaultError`` — replica failover
    material, not a silent hang)."""
    fetch, _ = _counting_fetch()
    win = StreamWindow(fetch, tag="stream-window",
                       retry=faults.RetryPolicy(max_retries=0))
    with faults.armed(faults.resolve("seed=0,transfer=1.0")):
        with pytest.raises(faults.TransientTransferError):
            win.acquire(7)


def test_stream_window_stalled_prefetch_recovers_via_demand_fetch():
    """An injected dead in-flight transfer (stall) is abandoned by the
    watchdog and demand re-fetched once: acquire still returns the right
    value, and the timeout is counted."""
    fetch, calls = _counting_fetch()
    win = StreamWindow(fetch, tag="stream-window",
                       retry=faults.RetryPolicy(watchdog_s=0.01))
    with faults.armed(faults.resolve("seed=0,stall=1.0")):
        win.prefetch(3)
        out = win.acquire(3)
    assert np.array_equal(out, np.full((4,), 3.0))
    assert win.timeouts == 1
    assert calls == [3, 3]          # prefetch + the recovery demand fetch


class _NeverReady:
    """A fake device buffer whose transfer never lands."""

    def is_ready(self) -> bool:
        return False


def test_stream_window_acquire_watchdog_regression():
    """Regression for the unbounded ``acquire()`` block: a transfer that
    never becomes ready used to hang forever; with a watchdog it now
    surfaces as ``StreamTimeoutError`` naming the window tag and key."""
    win = StreamWindow(lambda key: (_NeverReady(), 8), tag="expert-prefetch",
                       retry=faults.RetryPolicy(watchdog_s=0.01))
    with pytest.raises(faults.StreamTimeoutError) as ei:
        win.acquire((2, 5))
    msg = str(ei.value)
    assert "expert-prefetch" in msg and "(2, 5)" in msg
    assert win.timeouts >= 1


def test_stream_window_unarmed_counters_stay_zero():
    fetch, _ = _counting_fetch()
    win = StreamWindow(fetch)
    with faults.shielded():
        win.prefetch(0)
        win.acquire(0)
        win.acquire(1)
    assert (win.retries, win.timeouts) == (0, 0)
    assert win.take_fault_counters() == (0, 0)


# ---------------------------------------------------------------------------
# Server.submit hardening (satellite b)
# ---------------------------------------------------------------------------
def test_rejected_submit_leaves_server_state_untouched():
    """Validate-then-mutate: a rejected submit must not leak a handle, a
    heap entry, or KV bookkeeping — subsequent valid submits drain
    identically to a server that never saw the rejection."""
    cfg, params = _mixtral()
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    prompts = _prompts(cfg, 2, 6)
    mk = lambda: ServeConfig(scheduler="continuous", decode_len=4, max_seq=10)

    with faults.shielded():
        clean = Server(cfg, params, plan, serve=mk())
        for p in prompts:
            clean.submit(Request(p, 4))
        want = _tokens(clean.run())

        srv = Server(cfg, params, plan, serve=mk())
        with pytest.raises(ValueError):
            srv.submit(Request(list(range(1, 30)), 4))   # beyond max_seq
        with pytest.raises(ValueError):
            srv.submit(Request(prompts[0], 4, arrival_s=float("nan")))
        assert len(srv._handles) == 0
        assert len(srv._pending) == 0
        assert srv._kv_need == {}
        handles = [srv.submit(Request(p, 4)) for p in prompts]
        assert [h.index for h in handles] == [0, 1]   # indices unperturbed
        got = _tokens(srv.run())
    assert got == want


# ---------------------------------------------------------------------------
# Recovery end-to-end: token identity + nonzero counters
# ---------------------------------------------------------------------------
def test_transfer_faults_recover_token_identical_streamed():
    """Streamed weights under injected transient faults + stalls: served
    tokens equal the fault-free run, with retries/timeouts counted all the
    way into the ServeReport."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with faults.shielded():
        base = serve_dataset(cfg, params, [Request(p, 6) for p in prompts],
                             plan, 6, scheduler="continuous",
                             stream_weights=True, resident_bytes=0)
    armed = serve_dataset(cfg, params, [Request(p, 6) for p in prompts],
                          plan, 6, scheduler="continuous",
                          stream_weights=True, resident_bytes=0,
                          faults="seed=5,transfer=0.3,stall=0.1")
    assert _tokens(armed) == _tokens(base)
    assert armed.transfer_retries > 0
    assert base.prefill_tokens == armed.prefill_tokens


def test_page_oom_degrades_and_completes_token_identical():
    """Injected page-alloc OOM hits the degradation ladder (defer ->
    demote -> shrink) instead of raising; the run completes with the
    fault-free tokens and the deferrals counted."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with faults.shielded():
        base = serve_dataset(cfg, params, [Request(p, 6) for p in prompts],
                             plan, 6, scheduler="continuous",
                             kv_page_tokens=4)
    armed = serve_dataset(cfg, params, [Request(p, 6) for p in prompts],
                          plan, 6, scheduler="continuous", kv_page_tokens=4,
                          faults="seed=2,oom=0.5")
    assert _tokens(armed) == _tokens(base)
    assert armed.degrade_deferrals > 0


def test_page_table_oom_is_typed_and_transactional():
    """REAL frame exhaustion (no fault plan) raises the typed
    ``PageAllocOOM`` — not a bare assert — and rolls the partial row back
    so the admission layer can retry without leaking frames."""
    from repro.serving.cache import CacheConfig, KVPageTable

    cfg, _ = _mixtral()
    schema = [(cfg.layer_kind(i), cfg.ffn_kind(i))
              for i in range(cfg.num_layers)]
    tbl = KVPageTable(cfg, schema, batch=2, max_seq=8,
                      cache_cfg=CacheConfig(page_tokens=4))
    assert tbl.pages_per_seq == 2
    with faults.shielded():
        tbl.ensure_rows([0])
        # leave exactly ONE free frame: row 1 allocates it, fails on its
        # second page, and must give it back
        tbl._free_dev, spare = tbl._free_dev[:1], tbl._free_dev[1:]
        with pytest.raises(faults.PageAllocOOM):
            tbl.ensure_rows([1])
        assert (tbl.page_map[1] == -1).all()
        assert len(tbl._free_dev) == 1            # rollback returned it
        tbl._free_dev += spare
        tbl.ensure_rows([1])                      # retry succeeds
        assert (tbl.page_map[1] >= 0).all()


def test_injected_preemption_resumes_token_identical_zero_prefill():
    """The checkpoint/resume contract: an injected preemption schedule
    evicts running requests to host checkpoints and re-admits them with
    ZERO extra prefill launches; sampling keyed on (seed, token_index)
    makes the streams bit-identical."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with faults.shielded():
        base = serve_dataset(cfg, params, [Request(p, 8) for p in prompts],
                             plan, 8, scheduler="continuous")
    armed = serve_dataset(cfg, params, [Request(p, 8) for p in prompts],
                          plan, 8, scheduler="continuous",
                          faults="seed=3,preempt=3")
    assert _tokens(armed) == _tokens(base)
    assert armed.preemptions > 0
    assert armed.resumes == armed.preemptions
    # zero prefill relaunches: resume restores rows, it never re-prefills
    assert armed.prefill_tokens == base.prefill_tokens


def test_public_preempt_api_mid_run():
    """`Server.preempt(handle)` is the manual seam the injected schedule
    drives: evict a running request mid-drain, finish the rest, and the
    preempted stream still completes bit-identical."""
    cfg, params = _mixtral()
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0, decode_chunk=1)
    prompts = _prompts(cfg, 2)
    with faults.shielded():
        clean = Server(cfg, params, plan,
                       serve=ServeConfig(scheduler="continuous", decode_len=6))
        for p in prompts:
            clean.submit(Request(p, 6))
        want = _tokens(clean.run())

        srv = Server(cfg, params, plan,
                     serve=ServeConfig(scheduler="continuous", decode_len=6))
        handles = [srv.submit(Request(p, 6)) for p in prompts]
        srv.step()
        srv.step()
        assert handles[0].status == "running"
        assert srv.preempt(handles[0])
        assert handles[0].status == "preempted"
        assert not srv.preempt(handles[0])     # not running: no-op
        got = _tokens(srv.run())
    assert got == want
    assert srv.report.preemptions == 1 and srv.report.resumes == 1


def test_preemption_with_paged_kv_checkpoints_page_rows():
    """Mode B (host-tier pages): the checkpoint reads the slot's rows out
    of the page table and the resume re-reserves frames — still
    token-identical."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with faults.shielded():
        base = serve_dataset(cfg, params, [Request(p, 8) for p in prompts],
                             plan, 8, scheduler="continuous",
                             kv_page_tokens=4, device_kv_gb=1e-9)
    armed = serve_dataset(cfg, params, [Request(p, 8) for p in prompts],
                          plan, 8, scheduler="continuous",
                          kv_page_tokens=4, device_kv_gb=1e-9,
                          faults="seed=4,preempt=3")
    assert _tokens(armed) == _tokens(base)
    assert armed.preemptions > 0


def test_replica_kill_fails_over_token_identical():
    """The failover contract: a replica killed mid-drain loses its KV but
    its unfinished requests resubmit onto survivors and the merged drain
    is token-identical to a single fault-free Server."""
    from repro.distributed import ReplicaServer

    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0, decode_chunk=1)
    prompts = _prompts(cfg, 6)
    with faults.shielded():
        srv = Server(cfg, params, plan,
                     serve=ServeConfig(scheduler="continuous", decode_len=6))
        for p in prompts:
            srv.submit(Request(p, 6))
        want = _tokens(srv.run())

        rs = ReplicaServer(
            cfg, params, 2, plan=plan,
            serve=ServeConfig(scheduler="continuous", decode_len=6,
                              faults="seed=1,kill=1@3"),
            policy="round-robin")
        for p in prompts:
            rs.submit(Request(p, 6))
        rrep = rs.run()
    merged = rrep.merged
    assert _tokens(merged) == want
    assert merged.failovers == 1
    assert merged.requeued_requests > 0
    assert len(merged.request_results) == len(prompts)


def test_replica_kill_with_no_survivors_fails_loudly():
    from repro.distributed import ReplicaServer

    cfg, params = _mixtral()
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0, decode_chunk=1)
    with faults.shielded():
        rs = ReplicaServer(
            cfg, params, 1, plan=plan,
            serve=ServeConfig(scheduler="continuous", decode_len=4,
                              faults="seed=0,kill=0@1"))
        rs.submit(Request(_prompts(cfg, 1)[0], 4))
        with pytest.raises(faults.FaultError):
            rs.run()


# ---------------------------------------------------------------------------
# Unarmed no-op (acceptance criterion) + sanitizer integration
# ---------------------------------------------------------------------------
@pytest.mark.skipif(bool(os.environ.get("REPRO_FAULTS")),
                    reason="ambient chaos plan armed: unarmed-noop "
                           "byte-identity is not observable")
def test_unarmed_serving_is_byte_identical_noop():
    """With no fault plan, the fault seams add NOTHING: no fault-scope
    transfers, no retries, no checkpoints — strict sanitizer clean."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with analysis.sanitize(strict=True) as san:
        rep = serve_dataset(cfg, params, [Request(p, 6) for p in prompts],
                            plan, 6, scheduler="continuous",
                            stream_weights=True, resident_bytes=0,
                            kv_page_tokens=4)
    r = san.report()
    assert not any(t in r["planned_transfers"]
                   for t in ("fault-retry", "ckpt-save", "ckpt-restore"))
    assert rep.transfer_retries == 0 and rep.transfer_timeouts == 0
    assert rep.preemptions == 0 and rep.resumes == 0
    assert rep.degrade_deferrals == 0 and rep.chunk_shrinks == 0
    assert rep.failovers == 0 and rep.requeued_requests == 0


def test_armed_recovery_is_strict_sanitizer_clean():
    """Every recovery transfer rides a planned scope: the full chaos mix
    passes under sanitize(strict=True)."""
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    with analysis.sanitize(strict=True):
        rep = serve_dataset(
            cfg, params, [Request(p, 8) for p in prompts], plan, 8,
            scheduler="continuous", stream_weights=True, resident_bytes=0,
            kv_page_tokens=4,
            faults="seed=5,transfer=0.3,stall=0.1,oom=0.3,preempt=3")
    assert rep.transfer_retries > 0
    assert rep.preemptions > 0


def test_fault_report_records_injections_and_recoveries():
    cfg, params = _mixtral()
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    prompts = _prompts(cfg, 4)
    fp = faults.resolve("seed=5,transfer=0.3,stall=0.1")
    serve_dataset(cfg, params, [Request(p, 6) for p in prompts], plan, 6,
                  scheduler="continuous", stream_weights=True,
                  resident_bytes=0, faults=fp)
    rep = fp.report()
    assert any(k.startswith("injected:transfer") for k in rep["events"])
    assert any(k.startswith("recovered:transfer-retry") for k in rep["events"])


def test_launch_serve_exposes_faults_flag():
    """The launcher surface: ``--faults SPEC`` threads into ServeConfig
    and the recovery counters are printed after the run."""
    from repro.launch import serve as launch_serve

    src = open(launch_serve.__file__).read()
    assert "--faults" in src
    assert "faults=args.faults" in src
    assert "transfer_retries" in src and "failovers" in src
