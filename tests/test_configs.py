"""Config registry: every assigned architecture with its exact dimensions."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, list_archs
from repro.models.model import layer_pattern, num_groups

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "olmoe-1b-7b": (16, 2048, 16, 16, 0, 50304),
    "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 0, 32064),
}


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in EXPECTED:
        assert a in archs


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_exact_dimensions(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_configs():
    olmoe = get_config("olmoe-1b-7b")
    assert (olmoe.num_experts, olmoe.experts_per_token) == (64, 8)
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert (phi.num_experts, phi.experts_per_token) == (16, 2)
    jamba = get_config("jamba-1.5-large-398b")
    assert (jamba.num_experts, jamba.experts_per_token) == (16, 2)


def test_param_counts_plausible():
    # headline sizes should be within ~15% of the names
    approx = {
        "mamba2-370m": 0.37e9,
        "olmoe-1b-7b": 7e9,
        "internvl2-76b": 70e9,      # language backbone of the 76B VLM
        "qwen2-1.5b": 1.5e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "jamba-1.5-large-398b": 398e9,
        "mixtral-8x7b": 46.7e9,
    }
    for arch, n in approx.items():
        total = get_config(arch).param_counts()["total"]
        assert 0.7 * n < total < 1.35 * n, (arch, total, n)


def test_active_params_moe():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    c = cfg.param_counts()
    assert c["active"] < 0.3 * c["total"]          # 6.6B of 42B


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-1.5-large-398b")
    pattern = layer_pattern(cfg)
    assert len(pattern) == 8
    kinds = [k for k, _ in pattern]
    assert kinds.count("attn") == 1 and kinds[4] == "attn"   # 1:7 interleave
    ffns = [f for _, f in pattern]
    assert ffns.count("moe") == 4                            # MoE every other
    assert num_groups(cfg) == 9


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_smoke_configs_reduced():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        assert cfg.d_model <= 512
        assert cfg.num_layers <= 8
        assert cfg.num_experts <= 4


def test_sub_quadratic_census():
    subq = {a for a in ARCH_IDS if get_config(a).sub_quadratic}
    assert subq == {"mamba2-370m", "jamba-1.5-large-398b", "h2o-danube-1.8b"}
