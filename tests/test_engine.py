"""Module-based batching engine == model-based reference, plus engine stats."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine, unstack_layers
from repro.models import model as M
from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill

KEY = jax.random.PRNGKey(0)
B, S, DEC = 6, 16, 6


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "olmoe-1b-7b",
                                  "mamba2-370m", "jamba-1.5-large-398b"])
def test_engine_logits_match_reference(arch):
    """Per-step logits equal the model-based reference (bf16 tolerance)."""
    cfg, params, toks = _setup(arch)
    lg_ref, caches = M.prefill(cfg, params, toks)
    cache = cache_from_prefill(cfg, caches, S, max_seq=S + DEC)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=B, omega=0.0), max_seq=S + DEC
    )
    lg_eng = eng.prefill(toks)
    scale = float(jnp.max(jnp.abs(lg_ref.astype(jnp.float32)))) + 1e-6
    d0 = jnp.max(jnp.abs(lg_ref[:, 0].astype(jnp.float32) -
                         lg_eng.astype(jnp.float32)))
    assert float(d0) / scale < 0.05, d0
    nxt = jnp.argmax(lg_ref[:, 0], -1)
    lg2_ref, _ = M.decode_step(cfg, params, cache, nxt, jnp.int32(S))
    lg2_eng = eng.decode_step(nxt, S)
    d1 = jnp.max(jnp.abs(lg2_ref.astype(jnp.float32) -
                         lg2_eng.astype(jnp.float32)))
    assert float(d1) / scale < 0.05, d1


def test_engine_host_attention_path():
    """ω=1 (all attention on the host path, §B numerics) stays consistent."""
    cfg, params, toks = _setup("mixtral-8x7b")
    eng_dev = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=64, omega=0.0), max_seq=S + DEC
    )
    eng_host = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=64, omega=1.0), max_seq=S + DEC
    )
    eng_dev.prefill(toks)
    eng_host.prefill(toks)
    nxt = toks[:, 0]
    l_dev = eng_dev.decode_step(nxt, S)
    l_host = eng_host.decode_step(nxt, S)
    scale = float(jnp.max(jnp.abs(l_dev.astype(jnp.float32)))) + 1e-6
    d = float(jnp.max(jnp.abs(l_dev.astype(jnp.float32) -
                              l_host.astype(jnp.float32)))) / scale
    assert d < 0.06, d      # paper §B: BF16-consistent host arithmetic
    assert eng_host.stats.host_attn_tokens > 0
    assert eng_host.stats.device_attn_tokens == 0


def test_engine_microbatch_counts():
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.5)   # capacity B: no drops
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    eng.prefill(toks)
    eng.stats.attn_microbatches = 0
    eng.decode_step(toks[:, 0], S)
    n_attn_layers = sum(1 for k, _, _ in eng.layers if k == "attn")
    assert eng.stats.attn_microbatches == n_attn_layers * -(-B // 2)
    # grouped dispatch: exactly ONE expert launch per MoE layer per step,
    # and every routed token-copy was processed (no capacity drops)
    n_moe_layers = sum(1 for _, f, _ in eng.layers if f == "moe")
    assert eng.stats.expert_launches == n_moe_layers
    eng.sync_stats()
    assert eng.stats.expert_tokens == n_moe_layers * B * cfg.experts_per_token
    assert eng.stats.expert_tokens_dropped == 0


def test_engine_generation_runs_all_archs():
    for arch in ["qwen2-1.5b", "h2o-danube-1.8b", "phi3.5-moe-42b-a6.6b"]:
        cfg, params, toks = _setup(arch)
        eng = ModuleBatchingEngine(
            cfg, params, Plan(B=B, b_a=3, b_e=8, omega=0.0), max_seq=S + DEC
        )
        out = eng.generate(toks, DEC)
        assert out.shape == (B, DEC)
        assert int(out.max()) < cfg.vocab_size


def test_unstack_layers_roundtrip():
    cfg, params, _ = _setup("jamba-1.5-large-398b")
    layers = unstack_layers(cfg, params)
    assert len(layers) == cfg.num_layers
    kinds = [k for k, _, _ in layers]
    assert kinds.count("attn") == 1          # 8-layer smoke: one attn layer
