"""Module-based batching engine == model-based reference, plus engine stats."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine, unstack_layers
from repro.models import model as M
from repro.serving.generate import greedy_generate
from repro.serving.kvcache import cache_from_prefill

KEY = jax.random.PRNGKey(0)
B, S, DEC = 6, 16, 6


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "olmoe-1b-7b",
                                  "mamba2-370m", "jamba-1.5-large-398b"])
def test_engine_logits_match_reference(arch):
    """Per-step logits equal the model-based reference.

    Runs in float32: the engine's per-layer module launches and the
    reference's fused ``lax.scan`` reassociate bf16 reductions differently,
    and in deep random-weight smoke models (jamba: 8 layers) that eps-level
    noise is chaotically amplified through top-k routing flips.  f32 makes
    the comparison tight (~1e-6), i.e. a STRICTER structural-equivalence
    check; bf16 behavior is covered by the engine-vs-engine token-exactness
    tests (ragged generate, grouped-vs-loop, streamed-vs-resident)."""
    from dataclasses import replace

    cfg = replace(get_config(arch, smoke=True), dtype="float32")
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    lg_ref, caches = M.prefill(cfg, params, toks)
    cache = cache_from_prefill(cfg, caches, S, max_seq=S + DEC)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=B, omega=0.0), max_seq=S + DEC
    )
    lg_eng = eng.prefill(toks)
    scale = float(jnp.max(jnp.abs(lg_ref))) + 1e-6
    d0 = jnp.max(jnp.abs(lg_ref[:, 0] - lg_eng))
    assert float(d0) / scale < 1e-4, d0
    nxt = jnp.argmax(lg_ref[:, 0], -1)
    lg2_ref, _ = M.decode_step(cfg, params, cache, nxt, jnp.int32(S))
    lg2_eng = eng.decode_step(nxt, S)
    d1 = jnp.max(jnp.abs(lg2_ref - lg2_eng))
    assert float(d1) / scale < 1e-4, d1


def test_engine_host_attention_path():
    """ω=1 (all attention on the host path, §B numerics) stays consistent."""
    cfg, params, toks = _setup("mixtral-8x7b")
    eng_dev = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=64, omega=0.0), max_seq=S + DEC
    )
    eng_host = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=B, b_e=64, omega=1.0), max_seq=S + DEC
    )
    eng_dev.prefill(toks)
    eng_host.prefill(toks)
    nxt = toks[:, 0]
    l_dev = eng_dev.decode_step(nxt, S)
    l_host = eng_host.decode_step(nxt, S)
    scale = float(jnp.max(jnp.abs(l_dev.astype(jnp.float32)))) + 1e-6
    d = float(jnp.max(jnp.abs(l_dev.astype(jnp.float32) -
                              l_host.astype(jnp.float32)))) / scale
    assert d < 0.06, d      # paper §B: BF16-consistent host arithmetic
    assert eng_host.stats.host_attn_tokens > 0
    assert eng_host.stats.device_attn_tokens == 0


def test_engine_microbatch_counts():
    cfg, params, toks = _setup("mixtral-8x7b")
    plan = Plan(B=B, b_a=2, b_e=B, omega=0.5)   # capacity B: no drops
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    eng.prefill(toks)
    eng.stats.attn_microbatches = 0
    eng.decode_step(toks[:, 0], S)
    n_attn_layers = sum(1 for k, _, _ in eng.layers if k == "attn")
    # host/device segments micro-batched separately (the ω boundary splits
    # a straddling micro-batch so the realized host fraction is exact)
    n_host = int(round(plan.omega * B))
    n_mb = -(-n_host // 2) + -(-(B - n_host) // 2)
    assert eng.stats.attn_microbatches == n_attn_layers * n_mb
    # grouped dispatch: exactly ONE expert launch per MoE layer per step,
    # and every routed token-copy was processed (no capacity drops)
    n_moe_layers = sum(1 for _, f, _ in eng.layers if f == "moe")
    assert eng.stats.expert_launches == n_moe_layers
    eng.sync_stats()
    assert eng.stats.expert_tokens == n_moe_layers * B * cfg.experts_per_token
    assert eng.stats.expert_tokens_dropped == 0


def test_engine_generation_runs_all_archs():
    for arch in ["qwen2-1.5b", "h2o-danube-1.8b", "phi3.5-moe-42b-a6.6b"]:
        cfg, params, toks = _setup(arch)
        eng = ModuleBatchingEngine(
            cfg, params, Plan(B=B, b_a=3, b_e=8, omega=0.0), max_seq=S + DEC
        )
        out = eng.generate(toks, DEC)
        assert out.shape == (B, DEC)
        assert int(out.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-1.5-large-398b"])
def test_engine_ragged_generate_matches_per_sequence(arch):
    """Padded ragged batch generate == each sequence generated alone,
    token-for-token (prompt-length mask + per-sequence decode positions)."""
    import numpy as np

    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    lens = [16, 11, 7]
    S, DEC = max(lens), 5
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    padded = np.zeros((len(lens), S), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=3, b_a=2, b_e=16, omega=0.0), max_seq=S + DEC
    )
    got = np.asarray(eng.generate(jnp.asarray(padded), DEC,
                                  lengths=np.asarray(lens)))
    for i, p in enumerate(prompts):
        solo = ModuleBatchingEngine(
            cfg, params, Plan(B=1, b_a=1, b_e=16, omega=0.0), max_seq=S + DEC
        )
        ref = np.asarray(solo.generate(jnp.asarray(p)[None], DEC))
        assert np.array_equal(got[i], ref[0]), (i, got[i], ref[0])


def test_engine_ragged_prefill_unpadded_logits_gather():
    """Prefill logits of a padded shorter prompt equal the unpadded run's
    (the seed emitted logits at the PAD position for every shorter prompt)."""
    cfg, params, toks = _setup("mixtral-8x7b")
    n = 9                                         # a prompt shorter than S
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=B, omega=0.0), max_seq=S + DEC
    )
    lengths = jnp.asarray([S] * (B - 1) + [n])
    lg = eng.prefill(toks.at[B - 1, n:].set(0), lengths=lengths)
    solo = ModuleBatchingEngine(
        cfg, params, Plan(B=1, b_a=1, b_e=B, omega=0.0), max_seq=S + DEC
    )
    lg_solo = solo.prefill(toks[B - 1 :, :n])
    assert jnp.array_equal(lg[B - 1], lg_solo[0])


def test_omega_split_realized_host_fraction():
    """A micro-batch straddling round(ω·B) is split at the boundary, so the
    realized host fraction equals round(ω·B)/B exactly (the seed ran the
    straddling micro-batch entirely on the device path)."""
    cfg, params, toks = _setup("mixtral-8x7b")
    for omega in (0.5, 0.25, 0.75):
        eng = ModuleBatchingEngine(
            cfg, params, Plan(B=B, b_a=4, b_e=B, omega=omega), max_seq=S + DEC
        )
        eng.prefill(toks)
        eng.decode_step(toks[:, 0], S)
        n_attn = sum(1 for k, _, _ in eng.layers if k == "attn")
        want_host = int(round(omega * B)) * n_attn
        assert eng.stats.host_attn_tokens == want_host, omega
        assert eng.stats.device_attn_tokens == B * n_attn - want_host


def test_unstack_layers_roundtrip():
    cfg, params, _ = _setup("jamba-1.5-large-398b")
    layers = unstack_layers(cfg, params)
    assert len(layers) == cfg.num_layers
    kinds = [k for k, _, _ in layers]
    assert kinds.count("attn") == 1          # 8-layer smoke: one attn layer
