"""HLO collective parsing: synthetic snippets + a real jit'd module."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import (
    _shape_bytes,
    collective_stats,
    op_histogram,
    total_collective_bytes,
)

SYNTH = """\
HloModule test

%while_cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%while_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4]{0} get-tuple-element(%p), index=1
  %ag = f32[8]{0} all-gather(%x), dimensions={0}
  %ar = f32[8]{0} all-reduce(%ag), to_apply=%sum
  ROOT %t = (s32[], f32[4]) tuple(%i, %x)
}

ENTRY %main (a: f32[16]) -> f32[16] {
  %a = f32[16]{0} parameter(0)
  %w = (s32[], f32[4]) while(%init), condition=%while_cond, body=%while_body
  %ag2 = bf16[32,2]{1,0} all-gather(%a2), dimensions={0}
  ROOT %r = f32[16]{0} copy(%a)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[4]") == 16
    assert _shape_bytes("bf16[32,2]") == 128
    assert _shape_bytes("(f32[2], s32[3])") == 8 + 12


def test_loop_trip_attribution():
    stats = collective_stats(SYNTH)
    # in-loop all-gather: 7 trips x f32[8]=32B; entry all-gather bf16[32,2]=128B
    assert stats["all-gather"]["count"] == 8
    assert stats["all-gather"]["bytes"] == 7 * 32 + 128
    assert stats["all-reduce"]["count"] == 7
    # all-reduce weighted 2x in the total (ring RS+AG)
    total = total_collective_bytes(SYNTH)
    assert total == (7 * 32 + 128) + 2 * (7 * 32)


def test_real_module_collectives():
    """A psum under shard_map on a 1-device mesh still lowers an all-reduce."""
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from repro.sharding.specs import shard_map
    g = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P(),
                      check_vma=False)
    txt = jax.jit(g).lower(jnp.ones((8,))).compile().as_text()
    stats = collective_stats(txt)
    # 1-device all-reduce may be optimized away; parsing must not crash
    assert isinstance(stats, dict)


def test_op_histogram():
    h = op_histogram(SYNTH)
    assert h.get("all-gather", 0) >= 2
