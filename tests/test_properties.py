"""Hypothesis property tests on system invariants.

ALL hypothesis-based tests live in this module: it is skipped wholesale when
the optional ``hypothesis`` test extra is not installed (CI installs it via
``pip install -e ".[test]"``), so no other test file may import hypothesis.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import workload as W
from repro.core.dag import JobDag
from repro.core.dag_builder import Plan, estimate_decode
from repro.core.engine import ModuleBatchingEngine
from repro.core.planner import host_batch_limit
from repro.core.hardware import A5000_C2
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.models.layers import apply_rope


@settings(max_examples=25, deadline=None)
@given(
    pos=st.integers(0, 1_000_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_preserves_norm(pos, seed):
    """Rotary embedding is a rotation: vector norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 64))
    y = apply_rope(x, jnp.full((1, 1), pos), 10_000.0)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert bool(jnp.allclose(nx, ny, rtol=1e-4))


@settings(max_examples=25, deadline=None)
@given(ctx=st.integers(1, 100_000))
def test_kv_bytes_monotone_in_context(ctx):
    cfg = get_config("mixtral-8x7b")
    assert W.kv_bytes_per_seq(cfg, ctx) <= W.kv_bytes_per_seq(cfg, ctx + 64)


@settings(max_examples=25, deadline=None)
@given(ctx=st.integers(16, 65_536))
def test_host_limit_monotone_decreasing_in_context(ctx):
    """Longer contexts => fewer sequences fit in host memory (Eq. 2)."""
    cfg = get_config("mixtral-8x7b")
    assert host_batch_limit(cfg, A5000_C2, ctx) >= host_batch_limit(
        cfg, A5000_C2, ctx * 2
    )


@settings(max_examples=20, deadline=None)
@given(ctx=st.integers(1, 1 << 20))
def test_swa_kv_bytes_capped_by_window(ctx):
    cfg = get_config("h2o-danube-1.8b")
    cap = W.kv_bytes_per_seq(cfg, cfg.sliding_window)
    assert W.kv_bytes_per_seq(cfg, ctx) <= cap + 1e-6


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_causal_masking_property(b, s, seed):
    """Future tokens never influence current logits."""
    from repro.models import model as M

    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)
    base, _, _ = M.forward(cfg, params, toks)
    # perturb the last token: logits for positions < s-1 must be unchanged
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    pert, _, _ = M.forward(cfg, params, toks2)
    assert bool(
        jnp.allclose(
            base[:, : s - 1].astype(jnp.float32),
            pert[:, : s - 1].astype(jnp.float32),
            atol=1e-3,
        )
    )


# ---------------------------------------------------------------------------
# Engine invariants (moved from test_system.py: hypothesis lives here only)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    b_a=st.integers(1, 8),
    b_e=st.integers(4, 16),       # b_e is a per-expert capacity: >= B=4
)
def test_engine_invariant_to_microbatching(b_a, b_e):
    """Module-based batching is a pure re-ordering: outputs do not depend on
    (b_a, b_e) choices (up to bf16 noise) as long as the per-expert capacity
    b_e admits every routed token."""
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=b_a, b_e=b_e, omega=0.0), max_seq=16
    )
    eng.prefill(toks)
    logits = eng.decode_step(toks[:, 0], 8)
    eng_ref = ModuleBatchingEngine(
        cfg, params, Plan(B=4, b_a=4, b_e=1 << 20, omega=0.0), max_seq=16
    )
    eng_ref.prefill(toks)
    ref = eng_ref.decode_step(toks[:, 0], 8)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    d = float(jnp.max(jnp.abs(logits.astype(jnp.float32) -
                              ref.astype(jnp.float32)))) / scale
    assert d < 0.05, d


@settings(max_examples=5, deadline=None)
@given(
    lens=st.lists(st.integers(2, 12), min_size=2, max_size=4),
    seed=st.integers(0, 1000),
)
def test_ragged_padded_generate_matches_per_sequence(lens, seed):
    """Padded-batch generate is token-for-token identical to generating each
    sequence alone unpadded, for ANY mix of prompt lengths (the ragged-prompt
    correctness contract: pad masking + true-last-token logits + per-sequence
    decode positions)."""
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    S, DEC = max(lens), 3
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    padded = np.zeros((len(lens), S), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=len(lens), b_a=2, b_e=64, omega=0.0),
        max_seq=S + DEC,
    )
    got = np.asarray(eng.generate(jnp.asarray(padded), DEC,
                                  lengths=np.asarray(lens)))
    for i, p in enumerate(prompts):
        solo = ModuleBatchingEngine(
            cfg, params, Plan(B=1, b_a=1, b_e=64, omega=0.0), max_seq=S + DEC
        )
        ref = np.asarray(solo.generate(jnp.asarray(p)[None], DEC))
        assert np.array_equal(got[i], ref[0]), (lens, i)


@settings(max_examples=6, deadline=None)
@given(
    frac=st.floats(0.0, 1.2),
    prefetch=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_streamed_generate_matches_resident_any_budget(frac, prefetch, seed):
    """The weight-residency contract: for ANY resident budget (a fraction of
    the model bytes, realized by the greedy ``plan_residency`` fill) and
    either fetch mode, streamed generation is token-for-token identical to
    the fully-resident engine."""
    from repro.core import workload as W

    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (3, 8), 0,
                              cfg.vocab_size)
    plan = Plan(B=3, b_a=2, b_e=8, omega=0.0)
    ref = ModuleBatchingEngine(cfg, params, plan, max_seq=16).generate(toks, 4)
    eng = ModuleBatchingEngine(
        cfg, params, plan, max_seq=16, stream_weights=True,
        resident_bytes=frac * W.model_bytes(cfg), prefetch=prefetch,
    )
    got = eng.generate(toks, 4)
    assert bool(jnp.array_equal(ref, got)), (frac, prefetch)
    if not eng.store.fully_resident:
        assert eng.stats.weight_htod_bytes > 0


@settings(max_examples=8, deadline=None)
@given(
    lens=st.lists(st.integers(3, 10), min_size=1, max_size=4),
    chunk=st.integers(1, 6),
    temp=st.sampled_from([0.0, 0.7]),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_fused_chunk_generate_matches_per_module(lens, chunk, temp, seed):
    """The fused-decode contract: for ANY ragged batch, chunk length and
    sampling policy (greedy or seeded temperature), the fused one-launch
    multi-token chunk path generates tokens bit-identical to the
    per-module dispatch loop (``fused_decode=False``, the oracle)."""
    from repro.serving.sampling import SamplingParams

    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    S, DEC = max(lens), 4
    rng = np.random.default_rng(seed)
    padded = np.zeros((len(lens), S), np.int32)
    for i, n in enumerate(lens):
        padded[i, :n] = rng.integers(0, cfg.vocab_size, n)
    sp = SamplingParams(temperature=temp, seed=seed) if temp else None
    plan = Plan(B=len(lens), b_a=2, b_e=64, omega=0.0, decode_chunk=chunk)
    ref = ModuleBatchingEngine(
        cfg, params, plan, max_seq=S + DEC, fused_decode=False
    ).generate(jnp.asarray(padded), DEC, lengths=np.asarray(lens),
               sampling=sp, chunk=1)
    eng = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC)
    got = eng.generate(jnp.asarray(padded), DEC, lengths=np.asarray(lens),
                       sampling=sp)
    assert bool(jnp.array_equal(ref, got)), (lens, chunk, temp)
    assert eng.stats.fused_dispatches == -(-(DEC - 1) // chunk)


# ---------------------------------------------------------------------------
# Paged tiered KV cache (ISSUE 6)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    lens=st.lists(st.integers(3, 10), min_size=2, max_size=3),
    page=st.sampled_from([4, 8]),
    budget_frac=st.sampled_from([0.0, 0.5, 1.0]),
    swa=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_paged_generate_matches_contiguous(lens, page, budget_frac, swa, seed):
    """The paged-cache contract: for ANY page size, ragged batch, tier split
    (all-host, mixed, fully device-resident) and attention flavor (full or
    sliding-window ring), paged generation is token-for-token identical to
    the contiguous-buffer engine."""
    from repro.serving.cache import CacheConfig, KVPageTable

    cfg = get_config("h2o-danube-1.8b" if swa else "olmoe-1b-7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    S, DEC = max(lens), 3
    rng = np.random.default_rng(seed)
    padded = np.zeros((len(lens), S), np.int32)
    for i, n in enumerate(lens):
        padded[i, :n] = rng.integers(0, cfg.vocab_size, n)
    plan = Plan(B=len(lens), b_a=2, b_e=64, omega=0.0)
    ref = ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC).generate(
        jnp.asarray(padded), DEC, lengths=np.asarray(lens))
    if budget_frac >= 1.0:
        dpb = None
    else:
        schema = [(cfg.layer_kind(i), cfg.ffn_kind(i))
                  for i in range(cfg.num_layers)]
        probe = KVPageTable(cfg, schema, len(lens), S + DEC,
                            CacheConfig(page_tokens=page))
        dpb = budget_frac * probe.total_frames * probe.frame_bytes + 1.0
    eng = ModuleBatchingEngine(
        cfg, params, plan, max_seq=S + DEC,
        cache_config=CacheConfig(page_tokens=page, device_pool_bytes=dpb),
    )
    got = eng.generate(jnp.asarray(padded), DEC, lengths=np.asarray(lens))
    assert bool(jnp.array_equal(ref, got)), (lens, page, budget_frac, swa)
    if budget_frac < 1.0:
        assert eng.stats.kv_htod_bytes > 0


@functools.lru_cache(maxsize=1)
def _paged_serving_fixture():
    """Model + per-scheduler contiguous baselines shared by every example."""
    from repro.serving.scheduler import Request, serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plan = Plan(B=2, b_a=2, b_e=16, omega=0.0)
    rng = np.random.default_rng(13)
    shared = [int(t) for t in rng.integers(5, cfg.vocab_size - 5, size=9)]
    # prompt lengths 12, 11, 12: at page size 4 or 8 every prompt keys at
    # pspan=8, inside the 9-token shared span — one stored prefix serves all
    tails = [rng.integers(5, cfg.vocab_size - 5, n).tolist()
             for n in (3, 2, 3)]
    make = lambda: [Request(prompt=shared + [int(t) for t in tl], decode_len=4)
                    for tl in tails]
    base = {s: serve_dataset(cfg, params, make(), plan, 4, scheduler=s,
                             max_seq=24)
            for s in ("static", "continuous")}
    return cfg, params, plan, make, base


@settings(max_examples=6, deadline=None)
@given(
    scheduler=st.sampled_from(["static", "continuous"]),
    page=st.sampled_from([4, 8]),
    host=st.booleans(),
    prefix=st.booleans(),
)
def test_paged_serving_matches_contiguous_any_knobs(scheduler, page, host,
                                                    prefix):
    """End-to-end: for ANY scheduler x page size x tier x prefix-cache
    combination, served tokens equal the contiguous baseline — and
    prefix-cache runs on shared-prefix prompts register hits."""
    from repro.serving.scheduler import serve_dataset

    cfg, params, plan, make, base = _paged_serving_fixture()
    rep = serve_dataset(cfg, params, make(), plan, 4, scheduler=scheduler,
                        max_seq=24, kv_page_tokens=page,
                        device_kv_gb=(1e-9 if host else None),
                        prefix_cache=prefix)
    for a, b in zip(base[scheduler].request_results, rep.request_results):
        assert np.array_equal(a.tokens, b.tokens), (scheduler, page, host,
                                                    prefix, a.index)
    if host:
        assert rep.kv_htod_gb > 0.0
    if prefix:
        assert rep.prefix_hits >= 1


# ---------------------------------------------------------------------------
# Tokenizer (moved from test_serving.py)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.text(max_size=64))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text)
    assert tok.decode(list(ids)) == text


# ---------------------------------------------------------------------------
# DAG cost model (moved from test_planner.py)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(
    durations=st.lists(
        st.floats(0.0, 10.0, allow_nan=False), min_size=1, max_size=12
    ),
    bump=st.floats(0.1, 5.0, allow_nan=False),
    channels=st.lists(st.sampled_from(["gpu", "cpu", "htod"]), min_size=12,
                      max_size=12),
)
def test_dag_monotonicity(durations, bump, channels):
    """Increasing any job's duration never reduces the finish time."""
    def build(ds):
        dag = JobDag()
        prev = None
        for i, d in enumerate(ds):
            deps = [prev] if (prev is not None and i % 3 == 0) else []
            prev = dag.add(f"j{i}", channels[i], d, deps=deps)
        return dag.earliest_finish()

    base = build(durations)
    for i in range(len(durations)):
        bumped = list(durations)
        bumped[i] += bump
        assert build(bumped) >= base - 1e-9


@settings(max_examples=30, deadline=None)
@given(
    b_a=st.integers(1, 512),
    b_e=st.integers(1, 8192),
    omega=st.floats(0.0, 1.0),
)
def test_estimate_decode_total_positive(b_a, b_e, omega):
    cfg = get_config("mixtral-8x7b")
    plan = Plan(B=512, b_a=b_a, b_e=b_e, omega=omega)
    est = estimate_decode(cfg, A5000_C2, plan, 768)
    assert est.t_model > 0
    assert est.throughput > 0


# ---------------------------------------------------------------------------
# Serving: per-slot sampling isolation
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _mixed_batch_fixture():
    """Model + the all-greedy baseline, shared by every hypothesis example
    (nothing drawn feeds it, so serving it once per session suffices)."""
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    make = lambda: synthetic_requests(DatasetSpec("mix", 4, 8, 3),
                                      cfg.vocab_size,
                                      prompt_lens=[8, 5, 7, 6])
    base = serve_dataset(cfg, params, make(), plan, 3)
    return cfg, params, plan, make, base


@settings(max_examples=5, deadline=None)
@given(
    sampled=st.lists(st.booleans(), min_size=4, max_size=4),
    seed=st.integers(0, 2**31 - 1),
)
def test_mixed_sampled_batch_leaves_greedy_slots_identical(sampled, seed):
    """Per-slot sampling is isolated: in a batch mixing greedy and sampled
    slots, the greedy slots' tokens are identical to an all-greedy run
    (the sampled neighbours change nothing outside their own slot)."""
    from repro.serving.sampling import SamplingParams
    from repro.serving.scheduler import serve_dataset

    cfg, params, plan, make, base = _mixed_batch_fixture()
    reqs = make()
    for i, r in enumerate(reqs):
        r.sampling = (SamplingParams(temperature=0.8, seed=seed + i)
                      if sampled[i] else None)
    mixed = serve_dataset(cfg, params, reqs, plan, 3)
    for i, (a, b) in enumerate(zip(base.request_results,
                                   mixed.request_results)):
        if not sampled[i]:
            assert np.array_equal(a.tokens, b.tokens), i


# ---------------------------------------------------------------------------
# Predictive per-expert streaming: identity across the prediction seam
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _predictive_fixture():
    """Skewed-router MoE model + the fully-resident reference tokens, one
    per scheduler mode (nothing drawn feeds these, so once per session)."""
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # bias every router toward experts {0,1}: the imbalanced regime where
    # prediction and the hot-expert LRU actually have something to exploit
    for slot in params["layers"]:
        if "moe" in slot:
            r = np.asarray(slot["moe"]["router"]).copy()
            r[..., [0, 1]] += 4.0 * float(np.abs(r).mean() + 1e-6)
            slot["moe"]["router"] = jnp.asarray(r)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    make = lambda: synthetic_requests(DatasetSpec("pp", 4, 8, 4),
                                      cfg.vocab_size,
                                      prompt_lens=[8, 6, 7, 5])
    base = {
        sched: serve_dataset(cfg, params, make(), plan, 4, scheduler=sched)
        for sched in ("static", "continuous")
    }
    return cfg, params, plan, make, base


@settings(max_examples=8, deadline=None)
@given(
    khat=st.integers(1, 4),
    mode=st.sampled_from(["router", "constant", "random", "empty"]),
    lru=st.sampled_from([0.0, 1e9]),
    sched=st.sampled_from(["static", "continuous"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_predictive_streaming_token_identical(khat, mode, lru, sched, seed):
    """Predictive per-expert streaming NEVER changes tokens: for any
    predictor accuracy (the learned gate tap, a constant guess, random
    ids, or no prefetch at all), any k-hat, any LRU budget, and either
    scheduler, the served tokens equal the fully-resident reference.
    Prediction moves WHEN bytes move, never WHICH math runs."""
    from repro.serving.server import Server, ServeConfig, StreamConfig
    from repro.serving.weights import ParamStore

    cfg, params, plan, make, base = _predictive_fixture()
    store = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=khat,
                       lru_bytes=lru)
    server = Server(cfg, params, plan,
                    serve=ServeConfig(scheduler=sched, decode_len=4),
                    store=store)
    for r in make():
        server.submit(r)
    server._ensure_engine()
    if mode == "constant":
        server._engine.predictor = lambda nli, k: [0]
    elif mode == "random":
        rng = np.random.default_rng(seed)
        server._engine.predictor = (
            lambda nli, k: rng.integers(0, cfg.num_experts, k).tolist())
    elif mode == "empty":
        server._engine.predictor = lambda nli, k: []
    while server.step():
        pass
    rep = server.finalize()
    for a, b in zip(base[sched].request_results, rep.request_results):
        assert np.array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# Fault tolerance: chaos schedules are token-invisible (ISSUE 10)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _chaos_fixture():
    """Model + per-scheduler fault-free baselines (under faults.shielded()
    so an ambient REPRO_FAULTS chaos plan cannot perturb them).  Streamed
    weights + Mode B paging so every injection seam — weight window,
    page window, page allocator, preemption — is actually on the path."""
    from repro import faults
    from repro.serving.scheduler import Request, serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plan = Plan(B=4, b_a=2, b_e=64, omega=0.0)
    rng = np.random.default_rng(29)
    prompts = [rng.integers(1, cfg.vocab_size, 8).tolist() for _ in range(4)]
    make = lambda: [Request([int(t) for t in p], 8) for p in prompts]
    kw = dict(stream_weights=True, resident_bytes=0, kv_page_tokens=4,
              device_kv_gb=1e-9)
    with faults.shielded():
        base = {s: serve_dataset(cfg, params, make(), plan, 8, scheduler=s,
                                 **kw)
                for s in ("static", "continuous")}
    return cfg, params, plan, make, kw, base


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    transfer=st.sampled_from([0.0, 0.2, 0.5]),
    stall=st.sampled_from([0.0, 0.15]),
    oom=st.sampled_from([0.0, 0.4]),
    preempt=st.sampled_from([0, 3, 5]),
    scheduler=st.sampled_from(["static", "continuous"]),
)
def test_chaos_schedules_are_token_identical(seed, transfer, stall, oom,
                                             preempt, scheduler):
    """The recovery contract, adversarially: for ANY seeded fault plan
    mixing transient transfer failures, stalled in-flight copies, page
    OOMs, and preemption schedules, over EITHER scheduler, serving
    recovers to the exact fault-free token streams under
    sanitize(strict=True) — and every recovery is visible in the report
    counters, never silent."""
    from repro import analysis, faults
    from repro.serving.scheduler import serve_dataset

    cfg, params, plan, make, kw, base = _chaos_fixture()
    spec = (f"seed={seed},transfer={transfer},stall={stall},oom={oom},"
            f"preempt={preempt}")
    with analysis.sanitize(strict=True):
        rep = serve_dataset(cfg, params, make(), plan, 8,
                            scheduler=scheduler, faults=spec, **kw)
    for a, b in zip(base[scheduler].request_results, rep.request_results):
        assert np.array_equal(a.tokens, b.tokens), (spec, scheduler, a.index)
    # resumed checkpoints never relaunch prefill
    assert rep.prefill_tokens == base[scheduler].prefill_tokens
    recovered = (rep.transfer_retries + rep.transfer_timeouts +
                 rep.preemptions + rep.degrade_deferrals)
    fp = faults.resolve(spec)
    if transfer == 0.0 and stall == 0.0 and oom == 0.0 and (
            preempt == 0 or scheduler == "static"):
        assert recovered == 0, spec
    assert isinstance(fp, faults.FaultPlan)
