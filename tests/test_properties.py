"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import workload as W
from repro.core.planner import host_batch_limit
from repro.core.hardware import A5000_C2
from repro.models.layers import apply_rope


@settings(max_examples=25, deadline=None)
@given(
    pos=st.integers(0, 1_000_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_preserves_norm(pos, seed):
    """Rotary embedding is a rotation: vector norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 2, 64))
    y = apply_rope(x, jnp.full((1, 1), pos), 10_000.0)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert bool(jnp.allclose(nx, ny, rtol=1e-4))


@settings(max_examples=25, deadline=None)
@given(ctx=st.integers(1, 100_000))
def test_kv_bytes_monotone_in_context(ctx):
    cfg = get_config("mixtral-8x7b")
    assert W.kv_bytes_per_seq(cfg, ctx) <= W.kv_bytes_per_seq(cfg, ctx + 64)


@settings(max_examples=25, deadline=None)
@given(ctx=st.integers(16, 65_536))
def test_host_limit_monotone_decreasing_in_context(ctx):
    """Longer contexts => fewer sequences fit in host memory (Eq. 2)."""
    cfg = get_config("mixtral-8x7b")
    assert host_batch_limit(cfg, A5000_C2, ctx) >= host_batch_limit(
        cfg, A5000_C2, ctx * 2
    )


@settings(max_examples=20, deadline=None)
@given(ctx=st.integers(1, 1 << 20))
def test_swa_kv_bytes_capped_by_window(ctx):
    cfg = get_config("h2o-danube-1.8b")
    cap = W.kv_bytes_per_seq(cfg, cfg.sliding_window)
    assert W.kv_bytes_per_seq(cfg, ctx) <= cap + 1e-6


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_causal_masking_property(b, s, seed):
    """Future tokens never influence current logits."""
    from repro.models import model as M

    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)
    base, _, _ = M.forward(cfg, params, toks)
    # perturb the last token: logits for positions < s-1 must be unchanged
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    pert, _, _ = M.forward(cfg, params, toks2)
    assert bool(
        jnp.allclose(
            base[:, : s - 1].astype(jnp.float32),
            pert[:, : s - 1].astype(jnp.float32),
            atol=1e-3,
        )
    )
