"""SSD scan: chunked algorithm vs sequential recurrence; decode consistency."""
import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_scan

KEY = jax.random.PRNGKey(3)


def sequential_ssd(x, B_in, C_in, dt, A):
    """Token-by-token recurrence: h_t = h_{t-1} e^{dt A} + dt B x;  y = C h."""
    Bt, S, nh, hp = x.shape
    ns = B_in.shape[-1]
    h = jnp.zeros((Bt, nh, ns, hp))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)                              # (B,nh)
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bs,bnp,bn->bnsp", B_in[:, t], x[:, t], dt[:, t]
        )
        ys.append(jnp.einsum("bs,bnsp->bnp", C_in[:, t], h))
    return jnp.stack(ys, axis=1), h


def _inputs(Bt=2, S=64, nh=4, hp=8, ns=8):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, nh, hp)) * 0.5
    B_in = jax.random.normal(ks[1], (Bt, S, ns)) * 0.5
    C_in = jax.random.normal(ks[2], (Bt, S, ns)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[4], (nh,)) * 0.3)
    return x, B_in, C_in, dt, A


def test_chunked_equals_sequential():
    x, B_in, C_in, dt, A = _inputs()
    y_ref, h_ref = sequential_ssd(x, B_in, C_in, dt, A)
    for chunk in (16, 32, 64):
        y, h = ssd_scan(x, B_in, C_in, dt, A, chunk)
        assert jnp.max(jnp.abs(y - y_ref)) < 1e-3, chunk
        assert jnp.max(jnp.abs(h - h_ref)) < 1e-3, chunk


def test_chunk_size_invariance():
    x, B_in, C_in, dt, A = _inputs(S=128)
    y16, h16 = ssd_scan(x, B_in, C_in, dt, A, 16)
    y64, h64 = ssd_scan(x, B_in, C_in, dt, A, 64)
    assert jnp.max(jnp.abs(y16 - y64)) < 1e-3
    assert jnp.max(jnp.abs(h16 - h64)) < 1e-3


def test_ssm_decode_matches_full():
    """full-sequence block output at position t == step-by-step decode."""
    from repro.configs import get_config
    from repro.models.ssm import init_ssm_params, ssm_forward, ssm_decode

    cfg = get_config("mamba2-370m", smoke=True)
    p = init_ssm_params(cfg, KEY)
    B, S = 2, 16
    x = (jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1).astype(
        jnp.dtype(cfg.dtype)
    )
    y_full, state_full = ssm_forward(cfg, p, x)
    # replay token-by-token
    from repro.models.ssm import init_ssm_state

    st = init_ssm_state(cfg, B)
    outs = []
    for t in range(S):
        y, st = ssm_decode(cfg, p, x[:, t : t + 1], st)
        outs.append(y[:, 0])
    y_step = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(y_full.astype(jnp.float32) -
                           y_step.astype(jnp.float32)))
    assert diff < 0.05, diff          # bf16 path tolerance
    hdiff = jnp.max(jnp.abs(state_full["h"] - st["h"]))
    assert hdiff < 0.05, hdiff
