"""Modality frontend stubs: shapes, determinism, end-to-end through the
engine for the audio and VLM backbones."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.models import model as M
from repro.models.frontends import frontend_embeddings, frontend_spec

KEY = jax.random.PRNGKey(0)


def test_frontend_embedding_shapes():
    for arch in ("musicgen-medium", "internvl2-76b"):
        cfg = get_config(arch, smoke=True)
        assert cfg.frontend in ("audio", "vision")
        emb = frontend_embeddings(cfg, 3)
        assert emb.shape == (3, cfg.frontend_tokens, cfg.d_model)
        spec = frontend_spec(cfg, 3)
        assert spec.shape == emb.shape
        # deterministic (tests must be reproducible)
        emb2 = frontend_embeddings(cfg, 3)
        assert jnp.array_equal(emb, emb2)


def test_dense_arch_has_no_frontend():
    cfg = get_config("qwen2-1.5b", smoke=True)
    assert frontend_embeddings(cfg, 2) is None
    assert frontend_spec(cfg, 2) is None


def test_frontend_replaces_prefix_positions():
    cfg = get_config("musicgen-medium", smoke=True)
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fe = frontend_embeddings(cfg, B)
    base, _, _ = M.forward(cfg, params, toks, fe)
    # changing token ids under the frontend prefix must not matter
    toks2 = toks.at[:, : cfg.frontend_tokens].set(0)
    same, _, _ = M.forward(cfg, params, toks2, fe)
    assert jnp.array_equal(base, same)
    # changing tokens after the prefix must matter
    toks3 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    diff, _, _ = M.forward(cfg, params, toks3, fe)
    assert not jnp.array_equal(base[:, -1], diff[:, -1])


def test_engine_generates_with_frontend():
    cfg = get_config("musicgen-medium", smoke=True)
    params = M.init_params(cfg, KEY)
    B, S, DEC = 4, 24, 6
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    fe = frontend_embeddings(cfg, B)
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=16, omega=0.0), max_seq=S + DEC
    )
    out = eng.generate(toks, DEC, frontend_emb=fe)
    assert out.shape == (B, DEC)
    assert int(out.max()) < cfg.vocab_size
