"""Attention implementations against the naive oracle + decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (
    blocked_attention,
    naive_attention,
    swa_attention,
)

KEY = jax.random.PRNGKey(7)


def _qkv(B, S, H, K, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


@pytest.mark.parametrize("S,H,K,D", [(1024, 4, 2, 32), (2048, 2, 1, 64)])
def test_blocked_matches_naive(S, H, K, D):
    q, k, v = _qkv(2, S, H, K, D)
    out = blocked_attention(q, k, v, q_block=256, kv_block=256)
    ref = naive_attention(q, k, v)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_blocked_matches_naive_window():
    q, k, v = _qkv(1, 1024, 2, 2, 32)
    out = blocked_attention(q, k, v, window=128, q_block=256, kv_block=256)
    ref = naive_attention(q, k, v, window=128)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("window", [128, 256])
def test_swa_matches_naive(window):
    q, k, v = _qkv(2, 1024, 4, 2, 32)
    out = swa_attention(q, k, v, window=window, q_block=128)
    ref = naive_attention(q, k, v, window=window)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_swa_subquadratic_shape_independence():
    # the swa path only materializes window+block keys per block
    q, k, v = _qkv(1, 2048, 2, 1, 32)
    out = swa_attention(q, k, v, window=64, q_block=128)
    assert out.shape == q.shape
    ref = naive_attention(q, k, v, window=64)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_kv_mask_matches_unpadded_slice():
    """kv_mask-ed attention over a right-padded batch == attention over the
    unpadded per-sequence slice, at every valid query position."""
    import numpy as np

    B, S, H, K, D = 3, 16, 4, 2, 32
    lens = [16, 11, 7]
    q, k, v = _qkv(B, S, H, K, D)
    kv_mask = jnp.arange(S)[None, :] < jnp.asarray(lens)[:, None]
    out = naive_attention(q, k, v, kv_mask=kv_mask)
    blk = blocked_attention(q, k, v, q_block=8, kv_block=8, kv_mask=kv_mask)
    for i, n in enumerate(lens):
        ref = naive_attention(q[i : i + 1, :n], k[i : i + 1, :n],
                              v[i : i + 1, :n])
        assert jnp.max(jnp.abs(out[i, :n] - ref[0])) < 2e-5
        assert jnp.max(jnp.abs(blk[i, :n] - ref[0])) < 2e-5
    # without the mask, padded keys leak into valid queries' context
    bad = naive_attention(q, k, v)
    assert float(jnp.max(jnp.abs(bad[1, :7] - out[1, :7]))) == 0  # causal: q<7 sees k<7 anyway
    assert float(jnp.max(jnp.abs(bad[2, 8] - out[2, 8]))) > 0    # q=8 of len-7 seq attends pads


def test_decode_matches_full_attention():
    """prefill + decode of the next token == full forward at that position."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.kvcache import cache_from_prefill

    cfg = get_config("internlm2-1.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    # ground truth: full forward over S+1 tokens, logits at the last position
    full_logits, _, _ = M.forward(cfg, params, toks)
    want = full_logits[:, -1]
    # prefill S tokens, then decode token S
    _, caches = M.prefill(cfg, params, toks[:, :S])
    cache = cache_from_prefill(cfg, caches, S, max_seq=S + 4)
    got, _ = M.decode_step(cfg, params, cache, toks[:, S], jnp.int32(S))
    assert jnp.max(jnp.abs(want.astype(jnp.float32) -
                           got.astype(jnp.float32))) < 0.08  # bf16 tolerance


def test_decode_matches_full_swa():
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.kvcache import cache_from_prefill

    cfg = get_config("h2o-danube-1.8b", smoke=True)
    assert cfg.sliding_window > 0
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    full_logits, _, _ = M.forward(cfg, params, toks)
    want = full_logits[:, -1]
    _, caches = M.prefill(cfg, params, toks[:, :S])
    cache = cache_from_prefill(cfg, caches, S, max_seq=S + 4)
    got, _ = M.decode_step(cfg, params, cache, toks[:, S], jnp.int32(S))
    assert jnp.max(jnp.abs(want.astype(jnp.float32) -
                           got.astype(jnp.float32))) < 0.08
