"""Streamed parameter store: residency policy, prefetch, exactness.

The PR's contract (ISSUE 3): streamed-weights generation is token-for-token
identical to fully-resident generation; the greedy resident set matches the
planner's policy (base -> mixers -> dense FFNs -> expert stacks); htod
bytes and prefetch stalls are accounted; the planner only emits realizable
residency splits.  (The hypothesis-based streamed==resident property lives
in test_properties.py, the only module allowed to import hypothesis.)
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import planner, workload as W
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.core.hardware import A5000_C2
from repro.models import model as M
from repro.serving.weights import ParamStore

KEY = jax.random.PRNGKey(0)
B, S, DEC = 4, 12, 6


def _setup(arch, **over):
    cfg = get_config(arch, smoke=True)
    if over:
        cfg = replace(cfg, **over)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


def _generate(cfg, params, toks, **engine_kw):
    eng = ModuleBatchingEngine(
        cfg, params, Plan(B=B, b_a=2, b_e=B, omega=0.0), max_seq=S + DEC,
        **engine_kw,
    )
    out = eng.generate(toks, DEC)
    return out, eng


# ---------------------------------------------------------------------------
# Exactness: streamed == resident, token for token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["mixtral-8x7b",          # attention + MoE
                                  "mamba2-370m",           # pure SSM
                                  "jamba-1.5-large-398b"])  # hybrid
def test_streamed_generate_matches_resident(arch):
    """resident_bytes=0 (every per-layer module streamed) produces exactly
    the resident engine's tokens, with real htod traffic and no drops."""
    cfg, params, toks = _setup(arch)
    ref, _ = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks, stream_weights=True,
                         resident_bytes=0.0)
    assert jnp.array_equal(ref, got)
    assert eng.stats.weight_htod_bytes > 0
    assert eng.stats.expert_tokens_dropped == 0


@pytest.mark.parametrize("expert_path", ["grouped", "loop"])
@pytest.mark.parametrize("prefetch", [True, False])
def test_streamed_matches_resident_both_expert_paths(expert_path, prefetch):
    """Streaming is orthogonal to the MoE path: grouped and loop decode,
    overlapped and serial fetch, all reproduce the resident tokens."""
    cfg, params, toks = _setup("mixtral-8x7b")
    ref, _ = _generate(cfg, params, toks, expert_path=expert_path)
    got, eng = _generate(cfg, params, toks, expert_path=expert_path,
                         stream_weights=True, resident_bytes=0.0,
                         prefetch=prefetch)
    assert jnp.array_equal(ref, got)
    assert eng.stats.weight_htod_bytes > 0


def test_streamed_partial_budget_matches_resident():
    """A budget covering only part of the model (mixers resident, experts
    streamed) is still exact."""
    cfg, params, toks = _setup("mixtral-8x7b")
    budget = W.base_weight_bytes(cfg) + sum(
        W.mixer_weight_bytes(cfg, cfg.layer_kind(i))
        for i in range(cfg.num_layers)
    )
    ref, _ = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks, stream_weights=True,
                         resident_bytes=budget)
    assert jnp.array_equal(ref, got)
    rp = eng.store.residency
    assert all(rp.mixer_resident)            # mixers fit the budget...
    assert not any(                          # ...expert stacks do not
        rp.ffn_resident[i] for i in range(cfg.num_layers)
        if cfg.ffn_kind(i) == "moe"
    )
    assert eng.stats.weight_htod_bytes > 0


def test_streamed_everything_resident_is_noop():
    """A budget >= model bytes pins everything: no host set, no transfers."""
    cfg, params, toks = _setup("mixtral-8x7b")
    ref, _ = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks, stream_weights=True,
                         resident_bytes=float(W.model_bytes(cfg)) + 1e9)
    assert jnp.array_equal(ref, got)
    assert eng.store.fully_resident
    assert eng.stats.weight_htod_bytes == 0
    assert eng.stats.prefetch_wait_s == 0.0


def test_streamed_single_layer_model():
    """One layer: the prefetch window wraps onto the same layer (fetch for
    the NEXT step) and generation stays exact."""
    cfg, params, toks = _setup("mixtral-8x7b", num_layers=1)
    ref, _ = _generate(cfg, params, toks)
    got, eng = _generate(cfg, params, toks, stream_weights=True,
                         resident_bytes=0.0)
    assert jnp.array_equal(ref, got)
    assert eng.stats.weight_htod_bytes > 0


# ---------------------------------------------------------------------------
# ParamStore unit behavior
# ---------------------------------------------------------------------------
def test_store_greedy_fill_order_and_budget():
    """Greedy order: base always pinned; mixers before expert stacks; the
    realized resident bytes never exceed budget + base."""
    cfg, params, _ = _setup("mixtral-8x7b")
    zero = ParamStore(cfg, params, resident_bytes=0.0)
    assert not zero.fully_resident
    assert zero.residency.resident_bytes == pytest.approx(
        W.base_weight_bytes(cfg)
    )
    # enough for exactly one mixer
    one = W.base_weight_bytes(cfg) + W.mixer_weight_bytes(
        cfg, cfg.layer_kind(0)
    )
    st = ParamStore(cfg, params, resident_bytes=one)
    assert st.residency.mixer_resident[0]
    assert not any(
        st.residency.ffn_resident[i] for i in range(cfg.num_layers)
        if cfg.ffn_kind(i) == "moe"
    )
    full = ParamStore(cfg, params, resident_bytes=None)
    assert full.fully_resident
    assert full.streamed_module_bytes() == 0


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "olmoe-1b-7b",
                                  "qwen2-1.5b", "jamba-1.5-large-398b"])
def test_model_bytes_budget_realizes_fully_resident(arch):
    """The planner's fully-resident contract: a budget of exactly
    model_bytes pins EVERYTHING (the per-module policy sizes slightly
    exceed model_bytes — f32 router vs bf16 accounting — so this is a rule,
    not an emergent property of the greedy fill)."""
    for smoke in (True, False):
        cfg = get_config(arch, smoke=smoke)
        rp = W.plan_residency(cfg, W.model_bytes(cfg))
        assert rp.fully_resident, (arch, smoke)
        assert rp.n_streamed() == 0


def test_store_prefetch_window_bounded_and_counters_drain():
    cfg, params, _ = _setup("jamba-1.5-large-398b")
    st = ParamStore(cfg, params, resident_bytes=0.0, prefetch_depth=2)
    for li in range(len(st.schema)):
        st.prefetch(li)
        assert len(st._inflight) <= 2
    # acquire consumes the in-flight entry; on-demand fetch is counted
    st2 = ParamStore(cfg, params, resident_bytes=0.0)
    st2.prefetch(0)
    p = st2.acquire(0)
    assert "norm1" in p and 0 not in st2._inflight
    assert st2.demand_fetches == 0
    st2.acquire(1)                           # never prefetched
    assert st2.demand_fetches == 1
    htod, wait = st2.take_counters()
    assert htod > 0 and wait >= 0.0
    assert st2.take_counters() == (0, 0.0)   # drained


def test_store_prefetch_disabled_is_serial():
    cfg, params, _ = _setup("mixtral-8x7b")
    st = ParamStore(cfg, params, resident_bytes=0.0, prefetch=False)
    st.prefetch(0)                           # no-op
    assert not st._inflight
    st.acquire(0)
    assert st.demand_fetches == 1


# ---------------------------------------------------------------------------
# Planner emits realizable residency
# ---------------------------------------------------------------------------
def test_planned_residency_is_realizable():
    """search_decode's s_params is exactly the greedy fill's realized bytes
    and s_expert is the double-buffered stream window (or 0 when fully
    resident) — the executor can pin exactly what the planner charged."""
    cfg = get_config("mixtral-8x7b")
    res = planner.search_decode(cfg, A5000_C2, 768)
    plan = res.plan
    mb = W.model_bytes(cfg)
    if plan.s_params >= mb:
        assert plan.s_expert == 0.0
    else:
        # the window is sized for the plan's own streaming granularity:
        # whole-stack (predict_topk=0) or the predicted per-expert set
        assert plan.s_expert == pytest.approx(
            W.stream_buffer_bytes(cfg, 2, predict_topk=plan.predict_topk)
        )
        rp = W.plan_residency(cfg, plan.s_params)
        assert rp.resident_bytes == pytest.approx(plan.s_params)
        assert rp.n_streamed() > 0
    assert planner.device_memory_ok(cfg, A5000_C2, plan, 768, "decode")


def test_miss_fractions_follow_residency():
    """The DAG's htod charges follow the realized per-class residency: a
    budget that pins all mixers but no experts zeroes the attn miss and
    keeps the expert miss at 1."""
    from repro.core.dag_builder import _miss_fractions

    cfg = get_config("mixtral-8x7b")
    budget = W.base_weight_bytes(cfg) + cfg.num_layers * W.mixer_weight_bytes(
        cfg, "attn"
    )
    m = _miss_fractions(cfg, Plan(B=8, b_a=4, b_e=8, s_params=budget))
    assert m["attn"] == 0.0
    assert m["moe"] == 1.0
    m0 = _miss_fractions(cfg, Plan(B=8, b_a=4, b_e=8, s_params=0.0))
    assert m0["attn"] == 1.0 and m0["moe"] == 1.0


def test_plan_describe_is_reproducible():
    p = Plan(B=8, b_a=4, b_e=8, omega=0.3, phase="prefill", weight_reuse=3)
    d = p.describe()
    assert "phase=prefill" in d and "reuse=3" in d


# ---------------------------------------------------------------------------
# Serving surface
# ---------------------------------------------------------------------------
def test_serve_dataset_streaming_reports_htod():
    """ISSUE acceptance: ServeReport.htod_gb > 0 when s_params < model
    bytes, and streamed serving returns the resident tokens."""
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.scheduler import serve_dataset

    cfg = get_config("mixtral-8x7b", smoke=True)
    params = M.init_params(cfg, KEY)
    reqs = synthetic_requests(DatasetSpec("tiny", 4, 8, 4), cfg.vocab_size)
    plan = Plan(B=4, b_a=2, b_e=8, omega=0.0)
    ref = serve_dataset(cfg, params, reqs, plan, 4)
    assert ref.htod_gb == 0.0
    for sched in ("static", "continuous"):
        rep = serve_dataset(cfg, params, reqs, plan, 4, scheduler=sched,
                            stream_weights=True, resident_bytes=0.0)
        assert rep.htod_gb > 0.0
        assert rep.prefetch_wait_s >= 0.0
        for a, b in zip(ref.request_results, rep.request_results):
            assert np.array_equal(a.tokens, b.tokens), (sched, a.index)
