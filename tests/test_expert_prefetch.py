"""Predictive per-expert streaming, hot-expert LRU, capacity planning.

The ISSUE 8 contracts: predictive-streamed decode is token-identical to
resident decode with zero steady-state retraces and zero unplanned
transfers (prediction moves WHEN bytes move, never WHICH math runs); the
hot-expert LRU never exceeds its byte budget and demotes cold entries;
``capacity_for_load`` sizes ``b_e`` from the measured routing histogram;
grouped prefill buckets its capacity at the next pow2 over measured load;
drops and routed load are accounted per MoE layer.  (The hypothesis-based
predictor-accuracy property lives in test_properties.py, the only module
allowed to import hypothesis.)
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.configs import get_config
from repro.core import planner, workload as W
from repro.core.dag_builder import Plan
from repro.core.engine import ModuleBatchingEngine
from repro.models import model as M
from repro.serving.weights import ParamStore

KEY = jax.random.PRNGKey(0)
B, S, DEC = 4, 12, 6


def _setup(arch="mixtral-8x7b", **over):
    cfg = get_config(arch, smoke=True)
    if over:
        cfg = replace(cfg, **over)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _engine(cfg, params, store=None, plan=None, **kw):
    plan = plan or Plan(B=B, b_a=2, b_e=B, omega=0.0)
    return ModuleBatchingEngine(cfg, params, plan, max_seq=S + DEC,
                                store=store, **kw)


# ---------------------------------------------------------------------------
# Token identity + steady-state hygiene
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("khat", [1, 2, 4])
def test_predictive_streamed_matches_resident(khat):
    cfg, params, toks = _setup()
    ref = _engine(cfg, params).generate(toks, DEC)
    st = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=khat)
    eng = _engine(cfg, params, store=st)
    got = eng.generate(toks, DEC)
    assert jnp.array_equal(ref, got)
    eng.sync_stats()
    assert eng.stats.weight_htod_bytes > 0
    # the decode stage touched the per-expert path
    assert (eng.stats.expert_pred_hits + eng.stats.expert_pred_misses
            + eng.stats.expert_lru_hits) > 0


def test_predictive_steady_state_no_retrace_no_unplanned():
    """Steady-state predictive decode: every module hits its cached trace
    and every transfer runs under a planned scope (strict guard raises
    otherwise) — the MG105/sanitizer airtightness acceptance."""
    cfg, params, toks = _setup()
    st = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=2,
                    lru_bytes=1e9)
    eng = _engine(cfg, params, store=st)
    with analysis.sanitize(strict=True) as san:
        eng.prefill(toks)                       # warm: trace every module
        cur = toks[:, -1]
        for t in range(3):
            cur = jnp.argmax(eng.decode_step(cur, S + t), axis=-1)
        with san.steady():                      # steady: identical shapes
            for t in range(3, DEC):
                cur = jnp.argmax(eng.decode_step(cur, S + t), axis=-1)
    rep = san.report()
    assert rep["steady_retraces"] == {}
    assert rep["planned_transfers"].get("expert-prefetch", 0) > 0
    assert rep["planned_transfers"].get("prefill-capacity-probe", 0) > 0


def test_predictor_seam_prefetch_only():
    """An adversarial predictor (always-wrong / empty) changes WHICH bytes
    are staged, never the tokens: mispredictions demand-fetch."""
    cfg, params, toks = _setup()
    ref = _engine(cfg, params).generate(toks, DEC)
    for pred in (lambda nli, k: [], lambda nli, k: [cfg.num_experts - 1]):
        st = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=2,
                        lru_bytes=0.0)
        eng = _engine(cfg, params, store=st)
        eng.predictor = pred
        assert jnp.array_equal(ref, eng.generate(toks, DEC))
        eng.sync_stats()
        assert eng.stats.expert_pred_misses > 0   # wrong on purpose


# ---------------------------------------------------------------------------
# Hot-expert LRU
# ---------------------------------------------------------------------------
def test_lru_respects_byte_budget_and_demotes_cold():
    cfg, params, _ = _setup()
    per_expert = W.expert_weight_bytes(cfg)
    st = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=2,
                    lru_bytes=1.5 * per_expert)
    li = next(iter(st._experts_host))
    st.acquire_experts(li, [0])
    assert (li, 0) in st._lru
    st.acquire_experts(li, [1])                  # budget fits only one
    assert (li, 1) in st._lru and (li, 0) not in st._lru
    assert st._lru_used <= st.lru_bytes
    ec = st.take_expert_counters()
    assert ec["pred_misses"] == 2 and ec["lru_hits"] == 0
    st.acquire_experts(li, [1])                  # hot hit, no copy
    assert st.take_expert_counters()["lru_hits"] == 1


def test_lru_zero_budget_never_caches():
    cfg, params, _ = _setup()
    st = ParamStore(cfg, params, resident_bytes=0.0, predict_topk=2,
                    lru_bytes=0.0)
    li = next(iter(st._experts_host))
    st.acquire_experts(li, [0])
    st.acquire_experts(li, [0])
    assert not st._lru and st._lru_used == 0
    assert st.take_expert_counters()["lru_hits"] == 0


# ---------------------------------------------------------------------------
# Imbalance-aware capacity planning
# ---------------------------------------------------------------------------
def test_capacity_for_load_uniform_and_collapsed():
    E, Bt, k = 4, 8, 2
    uni = planner.capacity_for_load([1.0] * E, Bt, k)
    assert uni == Bt * k // E                    # balanced expected load
    hot = planner.capacity_for_load([1.0, 0.0, 0.0, 0.0], Bt, k)
    assert hot == Bt                             # fully collapsed: capped at B
    # relaxing the drop budget can only shrink the capacity
    for eps in (0.01, 0.1, 0.5):
        assert planner.capacity_for_load([3.0, 1.0, 1.0, 1.0], Bt, k, eps) \
            <= planner.capacity_for_load([3.0, 1.0, 1.0, 1.0], Bt, k, 0.0)
    # degenerate: no measurements -> balanced fallback
    assert planner.capacity_for_load([0.0] * E, Bt, k) >= 1


def test_search_decode_accepts_measured_load():
    cfg = get_config("mixtral-8x7b")
    from repro.core.hardware import A5000_C2

    res = planner.search_decode(cfg, A5000_C2, 512, B=64,
                                expert_load=[8.0, 1.0, 1.0, 1.0, 1.0, 1.0,
                                             1.0, 1.0])
    assert res.plan.b_e >= 1
    assert planner.device_memory_ok(cfg, A5000_C2, res.plan, 512, "decode")


def test_next_pow2():
    assert [W.next_pow2(n) for n in (0, 1, 2, 3, 5, 8, 9)] == \
        [1, 1, 2, 4, 8, 8, 16]


def test_engine_online_capacity_override():
    """set_expert_capacity(1) under-provisions and drops; None restores the
    plan's drop-free capacity — the Server re-plan entry point."""
    cfg, params, toks = _setup()
    eng = _engine(cfg, params)
    eng.prefill(toks)
    eng.set_expert_capacity(1)
    eng.decode_step(toks[:, -1], S)
    eng.sync_stats()
    dropped_tight = eng.stats.expert_tokens_dropped
    assert dropped_tight > 0
    eng.set_expert_capacity(None)
    eng.decode_step(toks[:, -1], S + 1)
    eng.sync_stats()
    assert eng.stats.expert_tokens_dropped == dropped_tight  # no new drops


def test_server_replan_on_skew_drift():
    """The Server's online re-plan: when the hottest expert's measured
    share drifts past replan_skew, b_e is re-derived from the measured
    histogram and pushed into the engine."""
    from repro.data.datasets import DatasetSpec, synthetic_requests
    from repro.serving.server import Server, ServeConfig

    cfg, params, _ = _setup()
    reqs = synthetic_requests(DatasetSpec("t", 4, 8, 8), cfg.vocab_size)
    server = Server(cfg, params, Plan(B=4, b_a=2, b_e=4, omega=0.0),
                    serve=ServeConfig(scheduler="continuous", decode_len=8,
                                      replan_skew=0.05))
    for r in reqs:
        server.submit(r)
    server._ensure_engine()
    rep_steps = 0
    while server.step():
        rep_steps += 1
    # force a drift and drive the re-plan cadence directly
    server._replan_share = -1.0
    server._replan_ticks = 7                    # next call hits the mod-8 gate
    server._maybe_replan()
    rep = server.finalize()
    assert rep.capacity_replans == 1
    assert server._engine._b_e_override is not None
    assert rep.expert_load is not None and rep.expert_load.sum() > 0


# ---------------------------------------------------------------------------
# Per-layer accounting + pow2-bucketed grouped prefill
# ---------------------------------------------------------------------------
def test_per_layer_drop_and_load_accounting():
    cfg, params, toks = _setup()
    eng = _engine(cfg, params, plan=Plan(B=B, b_a=2, b_e=1, omega=0.0))
    eng.generate(toks, DEC)
    st = eng.sync_stats()
    n_moe = sum(1 for _, f in eng.schema if f == "moe")
    assert st.expert_tokens_dropped_by_layer.shape == (n_moe,)
    assert st.expert_load.shape == (n_moe, cfg.num_experts)
    assert int(st.expert_tokens_dropped_by_layer.sum()) == \
        st.expert_tokens_dropped
    # routed copies = kept + dropped, per the pre-capacity histogram
    assert int(st.expert_load.sum()) == \
        st.expert_tokens + st.expert_tokens_dropped


def test_grouped_prefill_pow2_capacity_zero_drop():
    """The split grouped-prefill MoE stage sizes its dispatch buffer at the
    pow2 bucket over MEASURED load — strictly below the token-count upper
    bound for multi-expert configs — while keeping prefill zero-drop and
    the logits identical to the dense-reference prefill path."""
    cfg, params, toks = _setup()
    eng = _engine(cfg, params)
    with analysis.sanitize(strict=True) as san:
        lg = eng.prefill(toks)
    probes = san.report()["planned_transfers"].get("prefill-capacity-probe")
    n_moe = sum(1 for _, f in eng.schema if f == "moe")
    assert probes == n_moe * 2                  # one per layer x micro-batch
    eng.sync_stats()
    assert eng.stats.expert_tokens_dropped == 0
    ref_eng = _engine(cfg, params, grouped_prefill=False)
    ref = ref_eng.prefill(toks)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(ref, np.float32),
        atol=0.05 * cfg.d_model ** 0.5,
    )
