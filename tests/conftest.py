import os
import sys

# tests run on ONE CPU device (the dry-run sets its own 512-device flag in a
# separate process; never set it here — see launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
