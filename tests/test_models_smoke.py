"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward / train step on CPU, asserting output
shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.models.frontends import frontend_embeddings
from repro.train.optimizer import adamw_init
from repro.train.train_loop import make_train_step

B, S = 2, 64


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    fe = frontend_embeddings(cfg, B)
    return cfg, params, toks, fe


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_step(arch):
    cfg, params, toks, fe = _setup(arch)
    logits, aux, _ = M.forward(cfg, params, toks, fe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg, params, toks, fe = _setup(arch)
    cache = M.init_cache(cfg, B, S)
    logits, new_cache = M.decode_step(
        cfg, params, cache, toks[:, 0], jnp.int32(0)
    )
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize(
    "arch",
    [
        "mamba2-370m",            # ssm
        "olmoe-1b-7b",            # moe
        "jamba-1.5-large-398b",   # hybrid
        "qwen2-1.5b",             # dense GQA + bias
        "musicgen-medium",        # audio frontend stub
    ],
)
def test_train_step(arch):
    cfg, params, toks, fe = _setup(arch)
    labels = jnp.roll(toks, -1, axis=1)
    step = jax.jit(make_train_step(cfg, remat=True))
    opt = adamw_init(params)
    new_params, opt, metrics = step(params, opt, toks, labels, fe)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["gnorm"]))
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree.leaves(moved)) > 0.0
